"""Ragged multi-query sweep: bucketed one-dispatch batches vs per-query loop.

For a mixed stream of query sizes (the acceptance set is n in {64, 257,
1024}), this measures per backend:

* **ragged**: bucket the queries (:mod:`repro.core.bucketing`), dispatch one
  ``repro.api.find_medoids_ragged`` call per bucket;
* **loop**: the same queries through per-query ``find_medoid`` calls
  (what a naive service would do — one compilation per *distinct n*, one
  dispatch per query).

Contract assertions baked into the benchmark (mirroring the test-suite):

* every ragged medoid equals its per-query counterpart (exact-regime budget,
  so both recover the true medoid), and
* the ragged engine compiles at most ``ceil(log2(bucket(n_hi) /
  bucket(n_lo))) + 1`` distinct programs per backend for the whole sweep —
  the power-of-two bucket bound, independent of how many distinct n arrive.

On this CPU container the Pallas backends run in interpret mode (correctness
timings only); on TPU the same sweep is the serving-throughput comparison.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.api import find_medoid, find_medoids_ragged
from repro.core import (num_buckets_for_range, pack_queries,
                        plan_buckets)
from repro.core.corr_sh import ragged_compile_count


def _mixed_queries(ns, d: int, copies: int, seed: int = 0):
    key = jax.random.key(seed)
    qs = []
    for c in range(copies):
        for n in ns:
            qs.append(jax.random.normal(jax.random.fold_in(key, 1000 * c + n),
                                        (n, d)))
    return qs


def run(ns: tuple[int, ...] = (64, 257, 1024), d: int = 16, copies: int = 2,
        budget_per_arm: int | None = None,
        backends: tuple[str, ...] = ("reference", "pallas_fused"),
        seed: int = 0) -> list[dict]:
    rows = []
    qs = _mixed_queries(ns, d, copies, seed)
    lengths = [q.shape[0] for q in qs]
    plan = plan_buckets(lengths)
    compile_bound = num_buckets_for_range(min(lengths), max(lengths))
    key = jax.random.key(seed + 1)

    for backend in backends:
        # exact-regime budget per bucket unless told otherwise: both paths
        # recover the true medoid, so answers must agree query-for-query
        c0 = ragged_compile_count()
        answers_ragged: dict[int, int] = {}
        t_ragged = 0.0
        for nb, idxs in plan.items():
            group = [qs[i] for i in idxs]
            data, lens = pack_queries(group, pad_batch_to=len(group))
            bpa = (nb * 10) if budget_per_arm is None else budget_per_arm
            t0 = time.time()
            meds = jax.block_until_ready(
                find_medoids_ragged(data, lens, jax.random.fold_in(key, nb),
                                    budget_per_arm=bpa, metric="l2",
                                    backend=backend))
            dt = time.time() - t0
            # second identical dispatch: the program is traced and compiled
            # now, so this is the steady-state (serving) cost of the bucket
            t0 = time.time()
            meds2 = jax.block_until_ready(
                find_medoids_ragged(data, lens, jax.random.fold_in(key, nb),
                                    budget_per_arm=bpa, metric="l2",
                                    backend=backend))
            dt_steady = time.time() - t0
            meds = [int(m) for m in meds]
            assert meds == [int(m) for m in meds2], (
                f"same-key redispatch changed answers on bucket {nb}")
            t_ragged += dt
            for slot, i in enumerate(idxs):
                answers_ragged[i] = meds[slot]
            rows.append({
                "name": f"ragged_{backend}_bucket{nb}x{len(group)}x{d}",
                "us_per_call": round(dt * 1e6, 1),
                "steady_us": round(dt_steady * 1e6, 1),
                "derived": f"medoids={meds}",
            })
        compiles = ragged_compile_count() - c0

        bucket_of = {i: nb for nb, idxs in plan.items() for i in idxs}
        t0 = time.time()
        answers_loop = {}
        for i, q in enumerate(qs):
            nb = bucket_of[i]
            bpa = (nb * 10) if budget_per_arm is None else budget_per_arm
            # same total budget as before the facade port: ceil(bpa*nb / n)
            # per arm keeps the query in the exact regime its bucket implies
            answers_loop[i] = find_medoid(
                q, jax.random.fold_in(jax.random.fold_in(key, 7), i),
                budget_per_arm=-(-bpa * nb // q.shape[0]),
                metric="l2", backend=backend).medoid
        t_loop = time.time() - t0

        assert answers_ragged == answers_loop, (
            f"ragged/per-query medoid mismatch under {backend}: "
            f"{answers_ragged} vs {answers_loop}")
        assert compiles <= compile_bound, (
            f"{backend}: {compiles} ragged compilations for the sweep, "
            f"bucket bound is {compile_bound}")
        rows.append({
            "name": f"ragged_sweep_{backend}_{len(qs)}q",
            "us_per_call": round(t_ragged * 1e6, 1),
            "derived": (f"compiles={compiles}<=bound={compile_bound} "
                        f"buckets={sorted(plan)} loop_us={t_loop * 1e6:.0f}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']!r}")
