"""Live-corpus serving: maintenance pull ratio + EDF-vs-FIFO latency.

Two cells, mirroring the acceptance properties of ``repro.serve``:

* **maintenance ratio** — a seeded insert/delete stream through
  :class:`repro.serve.maintain.MaintainedMedoid` with an exact-regime
  budget, against the counterfactual of answering every mutation with a
  full correlated-SH re-run. The incremental protocol's whole point is
  that most mutations keep the incumbent for one O(n) n-vector; the
  ``pull_savings`` column is the measured ratio (counterfactual pulls /
  actual pulls) and ``kept_frac`` the fraction of mutations that never
  re-ran. The counterfactual is computed exactly from the round schedule
  at each mutation's capacity bucket — no second run needed.

* **EDF vs FIFO** — the same open-loop burst (mixed buckets, the last
  third carrying tight absolute deadlines) replayed against a FIFO server
  and an EDF server. Reported per policy: p50/p99 answer latency, the
  deadline hit rate over the deadlined third, and how many requests the
  policy shed as infeasible. Deadlines are sized from ONE measured warm
  dispatch (``4x`` its wall), so under FIFO the late-submitted deadlined
  requests sit behind the backlog and miss, while EDF reorders them to
  the front — the gap between the two hit rates is the cell's payload.
  Wall-clock numbers are machine-dependent; the hit-rate gap is the
  stable signal.

``python benchmarks/run.py --only serve`` writes ``BENCH_serve.json``.
"""
from __future__ import annotations

import math
import time

import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import bucket_n
from repro.engine import round_schedule, stop_round
from repro.launch.serve_medoid import MedoidServer
from repro.serve.corpus import CorpusStore
from repro.serve.maintain import MaintainedMedoid


def _rerun_pulls(n_bucket: int, budget_per_arm: int) -> int:
    """Scheduled pulls of one full re-run at this bucket (the exact number
    ``MaintainedMedoid._rerun`` charges — executed rounds only)."""
    rounds = round_schedule(n_bucket, budget_per_arm * n_bucket)
    return sum(r.pulls for r in rounds[: stop_round(rounds) + 1]) \
        if rounds else 0


def _maintenance_cell(n0: int, d: int, steps: int, seed: int,
                      backend: str) -> list[dict]:
    rng = np.random.default_rng(seed + 1)
    store = CorpusStore.from_points(
        rng.normal(size=(n0, d)).astype(np.float32), backend=backend)
    b = bucket_n(store.capacity, store.min_bucket)
    budget = b * max(1, math.ceil(math.log2(b)))    # exact regime
    mm = MaintainedMedoid(store, budget_per_arm=budget, seed=seed)

    # counterfactual accumulator: what "re-run on every mutation" would
    # cost, priced at each mutation's ACTUAL corpus size (n drifts over the
    # stream, so the per-step bucket must be read off as it happens)
    counterfactual = mm.rerun_pulls          # both pay the adoption re-run
    t0 = time.time()
    for _ in range(steps):
        if store.n == 0 or rng.random() < 0.5:
            mm.insert(rng.normal(size=d).astype(np.float32))
        else:
            mm.delete(int(rng.choice(store.live_slots())))
        mm.query()
        counterfactual += store.capacity + _rerun_pulls(
            bucket_n(max(1, store.n), store.min_bucket), mm.budget_per_arm)
    wall = time.time() - t0
    out = mm.stats()
    savings = counterfactual / out["total_pulls"]
    return [{
        "name": f"maintain_stream_{backend}_n{n0}x{steps}",
        "us_per_call": round(wall / steps * 1e6, 1),
        "pulls": out["total_pulls"],
        "derived": (f"kept_frac={out['kept_frac']:.3f} "
                    f"reruns={out['reruns']} "
                    f"incremental_pulls={out['incremental_pulls']} "
                    f"rerun_pulls={out['rerun_pulls']} "
                    f"pull_savings={savings:.2f}x"),
    }, {
        "name": f"maintain_counterfactual_rerun_every_n{n0}x{steps}",
        "us_per_call": "",
        "pulls": counterfactual,
        "derived": f"full re-run after each of {steps} mutations (computed)",
    }]


def _burst(server: MedoidServer, rng: np.random.Generator, *,
           num: int, sizes: tuple[int, ...], d: int,
           deadline_s: float) -> tuple[list[int], list[int]]:
    """Submit an open-loop burst; the last third carries ``deadline_s``
    (absolute). Returns (all rids, deadlined rids)."""
    rids, deadlined = [], []
    cut = num - num // 3
    for i in range(num):
        data = jnp.asarray(rng.normal(size=(sizes[i % len(sizes)], d)),
                           jnp.float32)
        if i >= cut:
            rid = server.submit(data, priority=1, deadline_s=deadline_s)
            deadlined.append(rid)
        else:
            rid = server.submit(data)
        rids.append(rid)
    return rids, deadlined


def _serving_cell(policy: str, *, num: int, sizes: tuple[int, ...], d: int,
                  budget_per_arm: int, max_batch: int, seed: int,
                  backend: str, unit_s: float) -> dict:
    rng = np.random.default_rng(seed)
    srv = MedoidServer(backend=backend, budget_per_arm=budget_per_arm,
                       max_batch=max_batch, policy=policy, seed=seed,
                       collect_gaps=False)
    srv.warmup([(n, d) for n in sizes])
    # one metered throwaway step: a fresh server's first live dispatch pays
    # host-side setup the open-loop measurement should not see
    srv.submit(jnp.asarray(rng.normal(size=(sizes[-1], d)), jnp.float32))
    srv.step()
    t0 = srv.now()
    rids, deadlined = _burst(srv, rng, num=num, sizes=sizes, d=d,
                             deadline_s=t0 + 4.0 * unit_s)
    steps = 0
    while srv.pending:
        srv.step()
        steps += 1
    lat = np.asarray([srv.done[r].finish_s - srv.done[r].submit_s
                      for r in rids if r in srv.done])
    hit = sum(1 for r in deadlined
              if r in srv.done and srv.done[r].deadline_met)
    s = srv.stats()
    return {
        "name": f"serve_{policy}_{backend}_x{num}",
        "us_per_call": round(float(np.percentile(lat, 50)) * 1e6, 1),
        "derived": (f"p50_us={np.percentile(lat, 50) * 1e6:.0f} "
                    f"p99_us={np.percentile(lat, 99) * 1e6:.0f} "
                    f"deadline_hit_rate={hit / len(deadlined):.2f} "
                    f"shed={s['shed']} dispatches={steps}"),
    }


def run(n0: int = 48, d: int = 16, steps: int = 120, num: int = 16,
        sizes: tuple[int, ...] = (40, 100), budget_per_arm: int = 8,
        max_batch: int = 2, backend: str = "reference",
        seed: int = 0) -> list[dict]:
    rows = _maintenance_cell(n0, d, steps, seed, backend)

    # size deadlines off one measured warm dispatch (compile excluded)
    probe = MedoidServer(backend=backend, budget_per_arm=budget_per_arm,
                         max_batch=max_batch, seed=seed, collect_gaps=False)
    probe.warmup([(n, d) for n in sizes])
    rng = np.random.default_rng(seed)
    # time the SECOND probe dispatch: the first pays one-time host-side
    # setup a steady serving loop never sees again
    for _ in range(2):
        probe.submit(jnp.asarray(rng.normal(size=(sizes[-1], d)),
                                 jnp.float32))
        t0 = time.time()
        probe.step()
        unit_s = max(time.time() - t0, 1e-4)

    for policy in ("fifo", "edf"):
        rows.append(_serving_cell(
            policy, num=num, sizes=sizes, d=d,
            budget_per_arm=budget_per_arm, max_batch=max_batch, seed=seed,
            backend=backend, unit_s=unit_s))
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
