"""Kernel-layer microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python) —
their numbers here are correctness artifacts, not performance. The XLA-jnp
distance blocks are the CPU-meaningful timing; the TPU story for the kernels
is the §Roofline/§Perf analysis. Each row: name, us_per_call, derived info.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.distances import pairwise
from repro.kernels import ops


def _time(f, *args, reps=5) -> float:
    jax.block_until_ready(f(*args))          # compile + warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def run(c: int = 1024, r: int = 1024, d: int = 512) -> list[dict]:
    key = jax.random.key(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (c, d))
    y = jax.random.normal(jax.random.fold_in(key, 2), (r, d))
    rows = []
    for metric in ("l1", "l2", "sql2", "cosine"):
        f = jax.jit(pairwise(metric))
        us = _time(f, x, y)
        flops = c * r * d * (2 if metric != "l1" else 3)
        rows.append({"name": f"xla_{metric}_{c}x{r}x{d}",
                     "us_per_call": round(us, 1),
                     "derived": f"{flops / (us / 1e6) / 1e9:.1f}GFLOP/s"})
    # interpret-mode kernel correctness spot-check (small, or it takes minutes)
    xs, ys = x[:128], y[:128]
    for name, kf, rf in (("dot", ops.kernel_dot, lambda a, b: a @ b.T),
                         ("l1", ops.kernel_l1, pairwise("l1"))):
        got = kf(xs, ys)
        want = rf(xs, ys)
        err = float(jnp.max(jnp.abs(got - want)))
        rows.append({"name": f"pallas_{name}_interpret_128x128x{d}",
                     "us_per_call": -1,
                     "derived": f"maxerr={err:.2e} (interpret=correctness only)"})
    return rows
