"""Quantized distance subsystem: throughput vs fp32 at asserted parity.

The quantized pipeline (PR 10, :mod:`repro.quant`) runs the Gram stage of
every round in bf16 or per-row-scaled int8, widens the halving survivor
margin by the precision's error model, and verifies the final survivor set
in exact fp32 — so the served answer is fp32-exact whenever the
verification certificate holds (and comes from a same-key fp32 fallback
when it doesn't). This section keeps that contract machine-checkable:

* one cell per precision (``fp32`` / ``bf16`` / ``int8``) on the n=1024
  engine workload (same shape as the BENCH_engine ragged cell), each with
  the compile/steady split of the engine sections;
* the quantized cells **assert** ``verified=True`` (the certificate held —
  no fallback ran) and that the final medoid is **identical** to the fp32
  cell's: any drift is a hard failure here, not a judgement call;
* ``pulls`` includes the verification epilogue's exact distance evals, so
  the quantized cells' pull overhead vs fp32 is visible in the JSON;
* a **hardness row** emits the instance's difficulty functionals
  (:mod:`repro.core.hardness`: the Δ₂ gap, dispersion σ, and the paper's
  H₂ / H̃₂ budgets) — the context that says *how hard* the instance the
  parity assertion ran on actually was.

``python benchmarks/run.py --only quant`` writes ``BENCH_quant.json``.
Throughput note: the bf16/int8 rate advantage is an MXU property; on CPU
the cells still measure (and assert parity), but ``ratio_vs_fp32`` may
not show a speedup.
"""
from __future__ import annotations

import time

import jax


def _cell(data, key, precision: str, budget_per_arm: int):
    from repro.api import find_medoid
    t0 = time.time()
    first = find_medoid(data, key, budget_per_arm=budget_per_arm,
                        precision=precision)
    compile_us = (time.time() - t0) * 1e6   # first call: trace + compile
    t0 = time.time()
    res = find_medoid(data, key, budget_per_arm=budget_per_arm,
                      precision=precision)
    steady_us = (time.time() - t0) * 1e6    # cached program dispatch
    assert res.medoid == first.medoid, \
        f"same-key {precision} re-run changed its answer"
    return res, compile_us, steady_us


def run(n: int = 1024, d: int = 16, seed: int = 0,
        budget_per_arm: int = 16) -> list[dict]:
    from repro.api import find_medoid
    from repro.core.hardness import hardness_stats

    key = jax.random.key(seed)
    data = jax.random.normal(jax.random.fold_in(key, 0), (n, d))
    qkey = jax.random.fold_in(key, 1)

    rows: list[dict] = []
    cells: dict[str, tuple] = {}
    for precision in ("fp32", "bf16", "int8"):
        cells[precision] = _cell(data, qkey, precision, budget_per_arm)

    fp32_res, _, fp32_steady = cells["fp32"]
    for precision, (res, compile_us, steady_us) in cells.items():
        derived = f"medoid={res.medoid} n={n} d={d} metric={res.metric}"
        if precision == "fp32":
            assert res.verified is None, "fp32 run carries no certificate"
        else:
            # acceptance: certificate held (no fallback) AND the answer is
            # the fp32 cell's, bit for bit
            assert res.verified is True, (
                f"{precision} verification certificate failed on the "
                f"benchmark workload (fallback would have run)")
            assert res.medoid == fp32_res.medoid, (
                f"{precision} medoid {res.medoid} != fp32 medoid "
                f"{fp32_res.medoid}")
            ratio = fp32_steady / steady_us if steady_us else float("nan")
            derived += (f" verified=True medoid_matches_fp32=True "
                        f"pull_overhead={res.pulls - fp32_res.pulls} "
                        f"ratio_vs_fp32={ratio:.2f}")
        rows.append({"name": f"quant_medoid_{precision}_n{n}",
                     "us_per_call": round(steady_us, 1),
                     "compile_us": round(compile_us, 1),
                     "steady_us": round(steady_us, 1),
                     "pulls": res.pulls, "derived": derived})

    # ---- hardness row: how hard was the instance parity was asserted on --
    hs = hardness_stats(data, metric="l2")
    rows.append({"name": f"quant_hardness_n{n}", "us_per_call": 0.0,
                 "derived": (f"delta2={float(hs.delta[1]):.5f} "
                             f"sigma={float(hs.sigma):.4f} "
                             f"h2={float(hs.h2):.1f} "
                             f"h2_tilde={float(hs.h2_tilde):.1f} "
                             f"budget={budget_per_arm * n}")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']!r}")
