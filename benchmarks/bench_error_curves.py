"""Fig 1 / Fig 5 analogue: error probability vs pulls-per-arm.

For each dataset family, sweep the corrSH budget (the paper's dotted-line
protocol: one run per fixed budget per seed) and measure RAND at matched
budgets. Prints one row per (dataset, algo, pulls_per_arm).
"""
from __future__ import annotations

import jax

from repro.api import find_medoid
from repro.core import exact_medoid, rand_medoid, schedule_pulls
from repro.data.medoid_datasets import DATASETS


def run(n: int = 1024, d: int = 256, trials: int = 40,
        budgets=(4, 8, 16, 32, 64)) -> list[dict]:
    rows = []
    for name, (metric, gen) in DATASETS.items():
        data = gen(jax.random.key(0), n, d)
        truth = int(exact_medoid(data, metric))
        for per_arm in budgets:
            errs = 0
            for s in range(trials):
                m = find_medoid(data, jax.random.key(1000 + s),
                                metric=metric, budget_per_arm=per_arm).medoid
                errs += m != truth
            rows.append({"dataset": name, "algo": "corrSH",
                         "pulls_per_arm": schedule_pulls(n, per_arm * n) / n,
                         "error": errs / trials})
            errs = 0
            for s in range(trials):
                m = int(rand_medoid(data, jax.random.key(2000 + s),
                                    num_refs=per_arm, metric=metric))
                errs += m != truth
            rows.append({"dataset": name, "algo": "rand",
                         "pulls_per_arm": per_arm, "error": errs / trials})
    return rows
