"""k-medoids: bandit (correlated-SH) pulls vs exact PAM.

Two cells:

* a **head-to-head** at a size where exact PAM actually runs in seconds
  (``n_small``): both algorithms on the same planted rnaseq-like data,
  reporting ARI, cost ratio, and measured pull counts; and
* the **acceptance cell** at ``n_big`` (CI scale 4096): the bandit pipeline
  runs for real; exact PAM's pull count needs no run — it is ``n^2`` by
  construction (the full distance matrix) — so the >= 10x pull gap and the
  ARI >= 0.95 recovery are asserted right here, mirroring
  ``tests/test_kmedoids.py``.

Rows carry a ``pulls`` field so ``run.py`` surfaces them in
``BENCH_cluster.json`` — the cross-PR perf trajectory for the clustering
workload.
"""
from __future__ import annotations

import time

import jax

from repro.api import kmedoids
from repro.cluster import adjusted_rand_index, pam_exact, pam_pulls
from repro.data.medoid_datasets import rnaseq_clusters


def run(n_small: int = 512, n_big: int = 4096, d: int = 64, k: int = 8,
        backend: str = "reference", seed: int = 0) -> list[dict]:
    rows = []
    key = jax.random.key(seed)

    # ---- head-to-head at exact-PAM-feasible scale ----
    data, labels = rnaseq_clusters(jax.random.fold_in(key, 1), n_small, d, k)
    t0 = time.time()
    res = kmedoids(data, k, jax.random.fold_in(key, 2), metric="l1",
                   backend=backend)
    t_bandit = time.time() - t0
    t0 = time.time()
    pam = pam_exact(data, k, "l1")
    t_pam = time.time() - t0
    rows.append({
        "name": f"kmedoids_bandit_{backend}_n{n_small}k{k}",
        "us_per_call": round(t_bandit * 1e6, 1),
        "pulls": res.pulls,
        "derived": (f"ari={adjusted_rand_index(res.labels, labels):.3f} "
                    f"cost_vs_pam={res.cost / pam.cost:.4f} "
                    f"swaps={res.swaps}"),
    })
    rows.append({
        "name": f"kmedoids_pam_exact_n{n_small}k{k}",
        "us_per_call": round(t_pam * 1e6, 1),
        "pulls": pam.pulls,
        "derived": (f"ari={adjusted_rand_index(pam.labels, labels):.3f} "
                    f"pull_ratio={pam.pulls / res.pulls:.1f}"),
    })

    # ---- acceptance cell: CI-scale bandit run vs PAM's n^2 pulls ----
    data, labels = rnaseq_clusters(jax.random.fold_in(key, 3), n_big, d, k)
    t0 = time.time()
    res = kmedoids(data, k, jax.random.fold_in(key, 4), metric="l1",
                   backend=backend)
    t_bandit = time.time() - t0
    ari = adjusted_rand_index(res.labels, labels)
    ratio = pam_pulls(n_big) / res.pulls
    assert ari >= 0.95, f"planted-cluster recovery ARI {ari:.3f} < 0.95"
    assert ratio >= 10.0, (
        f"bandit k-medoids used {res.pulls} pulls vs exact PAM's "
        f"{pam_pulls(n_big)} — ratio {ratio:.1f} < 10x")
    rows.append({
        "name": f"kmedoids_bandit_{backend}_n{n_big}k{k}",
        "us_per_call": round(t_bandit * 1e6, 1),
        "pulls": res.pulls,
        "derived": (f"ari={ari:.3f} pam_pulls={pam_pulls(n_big)} "
                    f"pull_ratio={ratio:.1f} swaps={res.swaps} "
                    f"build={res.build_pulls} refine={res.refine_pulls} "
                    f"swap={res.swap_pulls}"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']!r}")
