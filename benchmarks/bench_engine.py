"""One-program engine: answer parity vs PR 3 + compile/steady-state split.

PR 4 replaced the four per-workload round-loop copies with the single
estimator-parameterized ``repro.engine.run_halving`` behind ``repro.api``;
PR 6 made each workload's execution ONE compiled XLA program (banded
``lax.scan`` round loop, cached jitted entry points, device-resident
k-medoids phases). This section keeps the refactors' neutrality
machine-checkable across PRs:

* the **ragged cells** (mixed n in {64, 257, 1024}, the PR-2/3 serving
  acceptance sweep) and the **cluster head-to-head cell** (n=512, k=8 vs
  exact PAM) are re-run with the *same keys* as the committed PR-3 numbers;
* each cell's **answers must match exactly** (medoid indices, pull counts,
  accepted swaps — the engine is bit-exact, so any drift is a hard
  assertion failure here, not a judgement call);
* wall clock is now split: ``us_per_call`` is the **steady-state** cost
  (the program is compiled; this is what a serving loop pays per dispatch)
  and ``compile_us`` the first-call cost (tracing + XLA compilation, paid
  once per program signature — or never, with the persistent compile
  cache). The informational ``ratio_vs_pr3`` compares steady-state against
  the committed single-call numbers;
* the dispatch/trace odometers (:mod:`repro.engine.instrument`) are emitted
  as a final row of per-section **deltas** (``instrument.deltas()`` wraps
  the whole section) — NOT the process-lifetime totals, which depended on
  whatever ran earlier in the process and made the row change with section
  order. The dispatch-bound -> compute-bound shift stays visible per PR:
  steady-state traffic grows ``dispatches`` while ``traces`` stays put;
* a **telemetry cell** re-answers the n=257 single-query cell with the
  device-resident per-round trace enabled (:mod:`repro.obs.telemetry`):
  asserts the answer is bit-identical to telemetry-off and that the
  per-round pull column sums to the scheduled total, and emits the rows
  into ``BENCH_engine.json`` (schema: ``repro.obs.telemetry.FIELDS``).

``python benchmarks/run.py --only engine`` writes ``BENCH_engine.json``.
"""
from __future__ import annotations

import json
import os
import re
import time

import jax

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_ref(name: str, ref_dir: str) -> dict[str, dict]:
    path = os.path.join(ref_dir, name)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return {r["name"]: r for r in json.load(f)}


def _medoids_of(derived: str) -> str | None:
    """Extract the ``medoids=[...]`` answer text from a derived string (the
    rest of the string is timing/ratio commentary that may differ per PR)."""
    m = re.search(r"medoids=\[[^\]]*\]", str(derived))
    return m.group(0) if m else None


def run(d: int = 16, seed: int = 0, ref_dir: str | None = None) -> list[dict]:
    from benchmarks import bench_ragged
    from repro.api import KMedoidsConfig, find_medoid, kmedoids
    from repro.data.medoid_datasets import rnaseq_clusters
    from repro.engine import instrument

    ref_dir = ref_dir or _REPO
    rows: list[dict] = []
    section = instrument.deltas()
    section.__enter__()            # closed right before the counters row

    # ---- ragged cells through the facade (same keys as the PR-3 sweep) ----
    ref_ragged = _load_ref("BENCH_ragged.json", ref_dir)
    for r in bench_ragged.run(ns=(64, 257, 1024), d=d, seed=seed):
        row = {"name": f"engine_{r['name']}",
               "us_per_call": r["us_per_call"], "derived": r["derived"]}
        if "steady_us" in r:
            row["steady_us"] = r["steady_us"]
        ref = ref_ragged.get(r["name"])
        want = _medoids_of(ref.get("derived", "")) if ref else None
        if want is not None:
            got = _medoids_of(r["derived"])
            assert got == want, (
                f"unified engine changed ragged answers on {r['name']}: "
                f"{got} vs committed {want}")
            ratio = (r["us_per_call"] / ref["us_per_call"]
                     if ref["us_per_call"] else float("nan"))
            row["derived"] += f" answers_match_pr3=True ratio_vs_pr3={ratio:.2f}"
        rows.append(row)

    # ---- cluster head-to-head cell (bandit side; PAM side is n^2 always) ---
    ref_cluster = _load_ref("BENCH_cluster.json", ref_dir)
    n, k = 512, 8
    key = jax.random.key(seed)
    data, _ = rnaseq_clusters(jax.random.fold_in(key, 1), n, 64, k)
    t0 = time.time()
    res = kmedoids(data, k, jax.random.fold_in(key, 2),
                   config=KMedoidsConfig(metric="l1"))
    compile_us = (time.time() - t0) * 1e6      # first call: trace + compile
    t0 = time.time()
    res2 = kmedoids(data, k, jax.random.fold_in(key, 2),
                    config=KMedoidsConfig(metric="l1"))
    steady_us = (time.time() - t0) * 1e6       # every program is cached now
    assert (res2.medoids, res2.pulls, res2.swaps) == \
        (res.medoids, res.pulls, res.swaps), \
        "same-key kmedoids re-run changed its answer"
    derived = f"medoids={sorted(res.medoids)} swaps={res.swaps}"
    ref = ref_cluster.get(f"kmedoids_bandit_reference_n{n}k{k}")
    if ref and "pulls" in ref:
        assert res.pulls == ref["pulls"], (
            f"unified engine changed the cluster cell's pull count: "
            f"{res.pulls} vs committed {ref['pulls']}")
        m = re.search(r"swaps=(\d+)", str(ref.get("derived", "")))
        if m:
            assert res.swaps == int(m.group(1)), (
                f"unified engine changed SWAP behavior: {res.swaps} accepted "
                f"swaps vs committed {m.group(1)}")
        ratio = (steady_us / ref["us_per_call"] if ref["us_per_call"]
                 else float("nan"))
        derived += f" pulls_match_pr3=True ratio_vs_pr3={ratio:.2f}"
    rows.append({"name": f"engine_kmedoids_bandit_n{n}k{k}",
                 "us_per_call": round(steady_us, 1),
                 "compile_us": round(compile_us, 1),
                 "pulls": res.pulls, "derived": derived})

    # ---- telemetry cell: per-round trace rides the n=257 query for free ----
    n_tel = 257
    key_tel = jax.random.fold_in(jax.random.key(seed), 3)
    data_tel = jax.random.normal(jax.random.fold_in(key_tel, 0), (n_tel, d))
    plain = find_medoid(data_tel, jax.random.fold_in(key_tel, 1))
    t0 = time.time()
    traced = find_medoid(data_tel, jax.random.fold_in(key_tel, 1),
                         telemetry=True)
    compile_us = (time.time() - t0) * 1e6   # telemetry variant's first trace
    t0 = time.time()
    traced2 = find_medoid(data_tel, jax.random.fold_in(key_tel, 1),
                          telemetry=True)
    steady_us = (time.time() - t0) * 1e6
    assert traced.medoid == plain.medoid == traced2.medoid, \
        "telemetry changed the answer"
    tel = {k: v.tolist() for k, v in traced.telemetry.items()}
    assert sum(tel["pulls"]) == plain.pulls, \
        (f"telemetry pull rows sum to {sum(tel['pulls'])}, "
         f"scheduled total is {plain.pulls}")
    rows.append({"name": f"engine_telemetry_n{n_tel}",
                 "us_per_call": round(steady_us, 1),
                 "compile_us": round(compile_us, 1),
                 "pulls": plain.pulls, "telemetry": tel,
                 "derived": (f"medoid={plain.medoid} identical_to_plain=True "
                             f"rounds={len(tel['pulls'])} "
                             f"pull_rows_sum={sum(tel['pulls'])}")})

    # ---- section odometer deltas: dispatch-bound -> compute-bound story ----
    # (deltas, not process-lifetime totals: totals made this row depend on
    # whatever ran earlier in the process, so BENCH_engine.json changed with
    # section execution order)
    section.__exit__(None, None, None)
    c = section.counters()
    rows.append({"name": "engine_dispatch_counters", "us_per_call": 0.0,
                 "counters": c,
                 "derived": (f"traces={sum(c['traces'].values())} "
                             f"dispatches={sum(c['dispatches'].values())} "
                             f"per_kind={json.dumps(c['traces'])}")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']!r}")
