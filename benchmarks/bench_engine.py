"""Unified-engine perf neutrality: the PR-4 facade re-runs the PR-3 cells.

PR 4 replaced the four per-workload round-loop copies with the single
estimator-parameterized ``repro.engine.run_halving`` behind ``repro.api``.
This section makes the refactor's neutrality machine-checkable across PRs:

* the **ragged cells** (mixed n in {64, 257, 1024}, the PR-2/3 serving
  acceptance sweep) and the **cluster head-to-head cell** (n=512, k=8 vs
  exact PAM) are re-run through the facade with the *same keys* as the
  committed PR-3 numbers;
* each cell is diffed against the committed ``BENCH_ragged.json`` /
  ``BENCH_cluster.json``: **answers must match exactly** (medoids text,
  pull counts — the engine is bit-exact, so any drift is a hard assertion
  failure here, not a judgement call), while wall-clock is reported as an
  informational ``ratio`` (CI machines vary; pulls don't).

``python benchmarks/run.py --only engine`` writes ``BENCH_engine.json``.
"""
from __future__ import annotations

import json
import os
import re
import time

import jax

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_ref(name: str, ref_dir: str) -> dict[str, dict]:
    path = os.path.join(ref_dir, name)
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return {r["name"]: r for r in json.load(f)}


def run(d: int = 16, seed: int = 0, ref_dir: str | None = None) -> list[dict]:
    from benchmarks import bench_ragged
    from repro.api import KMedoidsConfig, kmedoids
    from repro.data.medoid_datasets import rnaseq_clusters

    ref_dir = ref_dir or _REPO
    rows: list[dict] = []

    # ---- ragged cells through the facade (same keys as the PR-3 sweep) ----
    ref_ragged = _load_ref("BENCH_ragged.json", ref_dir)
    for r in bench_ragged.run(ns=(64, 257, 1024), d=d, seed=seed):
        row = {"name": f"engine_{r['name']}",
               "us_per_call": r["us_per_call"], "derived": r["derived"]}
        ref = ref_ragged.get(r["name"])
        if ref and "medoids=" in str(ref.get("derived", "")):
            match = ref["derived"] == r["derived"]
            assert match, (
                f"unified engine changed ragged answers on {r['name']}: "
                f"{r['derived']} vs committed {ref['derived']}")
            ratio = (r["us_per_call"] / ref["us_per_call"]
                     if ref["us_per_call"] else float("nan"))
            row["derived"] += f" answers_match_pr3=True ratio_vs_pr3={ratio:.2f}"
        rows.append(row)

    # ---- cluster head-to-head cell (bandit side; PAM side is n^2 always) ---
    ref_cluster = _load_ref("BENCH_cluster.json", ref_dir)
    n, k = 512, 8
    key = jax.random.key(seed)
    data, _ = rnaseq_clusters(jax.random.fold_in(key, 1), n, 64, k)
    t0 = time.time()
    res = kmedoids(data, k, jax.random.fold_in(key, 2),
                   config=KMedoidsConfig(metric="l1"))
    us = (time.time() - t0) * 1e6
    derived = f"medoids={sorted(res.medoids)} swaps={res.swaps}"
    ref = ref_cluster.get(f"kmedoids_bandit_reference_n{n}k{k}")
    if ref and "pulls" in ref:
        assert res.pulls == ref["pulls"], (
            f"unified engine changed the cluster cell's pull count: "
            f"{res.pulls} vs committed {ref['pulls']}")
        m = re.search(r"swaps=(\d+)", str(ref.get("derived", "")))
        if m:
            assert res.swaps == int(m.group(1)), (
                f"unified engine changed SWAP behavior: {res.swaps} accepted "
                f"swaps vs committed {m.group(1)}")
        ratio = us / ref["us_per_call"] if ref["us_per_call"] else float("nan")
        derived += f" pulls_match_pr3=True ratio_vs_pr3={ratio:.2f}"
    rows.append({"name": f"engine_kmedoids_bandit_n{n}k{k}",
                 "us_per_call": round(us, 1), "pulls": res.pulls,
                 "derived": derived})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']!r}")
