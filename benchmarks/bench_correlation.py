"""Fig 3 + Fig 4 analogue: the correlation statistics that power the paper.

* Fig 3: std of correlated differences d(1,J)-d(i,J) vs independent
  d(1,J1)-d(i,J2), for a near arm and a far arm.
* Fig 4: rho_i vs Delta_i relationship summary + H2 / H~2 ratio per dataset.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hardness_stats
from repro.core.distances import full_distance_matrix
from repro.data.medoid_datasets import DATASETS


def run(n: int = 1024, d: int = 256) -> list[dict]:
    rows = []
    for name, (metric, gen) in DATASETS.items():
        data = gen(jax.random.key(0), n, d)
        hs = hardness_stats(data, metric)
        dm = np.asarray(full_distance_matrix(data, metric))
        order = np.asarray(hs.order)
        best = order[0]

        for which, idx in (("near", order[max(1, n // 100)]),
                           ("far", order[n // 2])):
            diffs_corr = dm[best] - dm[idx]                  # same reference
            rng = np.random.default_rng(0)
            j1 = rng.integers(0, n, 20000)
            j2 = rng.integers(0, n, 20000)
            diffs_ind = dm[best, j1] - dm[idx, j2]           # independent refs
            rows.append({
                "dataset": name, "arm": which,
                "delta": round(float(np.mean(dm[idx]) - np.mean(dm[best])), 5),
                "std_correlated": round(float(np.std(diffs_corr)), 5),
                "std_independent": round(float(np.std(diffs_ind)), 5),
                "variance_reduction": round(
                    float(np.var(diffs_ind) / max(np.var(diffs_corr), 1e-12)), 2),
            })

        delta = np.asarray(hs.delta)[1:]
        rho = np.asarray(hs.rho)[1:]
        near = delta < np.quantile(delta, 0.1)
        far = delta > np.quantile(delta, 0.9)
        rows.append({
            "dataset": name, "arm": "summary",
            "sigma": round(float(hs.sigma), 5),
            "mean_rho_near_arms": round(float(rho[near].mean()), 4),
            "mean_rho_far_arms": round(float(rho[far].mean()), 4),
            "h2": round(float(hs.h2), 1),
            "h2_tilde": round(float(hs.h2_tilde), 1),
            "h2_ratio": round(float(hs.h2 / hs.h2_tilde), 2),
        })
    return rows
