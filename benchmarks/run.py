"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the scaffold contract), one
section per benchmark, and writes each section's rows as machine-readable
``BENCH_<section>.json`` (``--out-dir``, default cwd) so the perf trajectory
is tracked across PRs. Scale knobs are CI-sized; pass --full for paper-scale.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _normalize(r: dict) -> dict:
    """One canonical row: name, us_per_call, optional pulls, derived."""
    name = r.get("name") or "_".join(
        str(r.get(k)) for k in ("dataset", "algo", "arm", "pulls_per_arm")
        if r.get(k) is not None)
    # NB: `sec == 0.0` is a legitimate value — test membership, never truth
    # (`r.get("sec", 0) and ...` used to short-circuit to 0 and print an
    # empty/zero us_per_call for instant calls).
    if "us_per_call" in r:
        us = r["us_per_call"]
    elif "sec" in r:
        us = r["sec"] * 1e6
    else:
        us = ""
    derived = r.get("derived") or json.dumps(
        {k: v for k, v in r.items()
         if k not in ("name", "us_per_call", "sec", "dataset", "algo")})
    out = {"name": name, "us_per_call": us, "derived": derived}
    if "pulls" in r:
        out["pulls"] = r["pulls"]
    # first-call (trace+compile) vs steady-state split, where a section
    # reports it — us_per_call alone conflates one-time compilation with
    # the recurring serving cost the one-program engine optimizes for
    for key in ("compile_us", "steady_us", "counters", "telemetry"):
        if key in r:
            out[key] = r[key]
    return out


def _emit(rows):
    normalized = [_normalize(r) for r in rows]
    for r in normalized:
        print(f"{r['name']},{r['us_per_call']},{r['derived']!r}")
        sys.stdout.flush()
    return normalized


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", default=None,
                    choices=[None, "algorithms", "curves", "correlation",
                             "kernels", "backends", "ragged", "cluster",
                             "engine", "serve", "quant", "roofline"])
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_<section>.json files are written")
    args = ap.parse_args()
    scale = 2 if args.full else 1

    from benchmarks import (bench_algorithms, bench_backends, bench_cluster,
                            bench_correlation, bench_engine,
                            bench_error_curves, bench_kernels, bench_quant,
                            bench_ragged, bench_serve, roofline_table)

    sections = {
        "algorithms": lambda: bench_algorithms.run(
            n=2048 * scale, d=256 * scale, trials=10 * scale),
        "curves": lambda: bench_error_curves.run(
            n=1024 * scale, d=128 * scale, trials=20 * scale),
        "correlation": lambda: bench_correlation.run(
            n=1024 * scale, d=256 * scale),
        "kernels": lambda: bench_kernels.run(),
        "backends": lambda: bench_backends.run(
            grid=((512 * scale, 64 * scale), (1024 * scale, 128 * scale))),
        "ragged": lambda: bench_ragged.run(
            ns=(64, 257, 1024), d=16 * scale),
        "cluster": lambda: bench_cluster.run(
            n_small=512, n_big=4096, d=64 * scale),
        "engine": lambda: bench_engine.run(d=16 * scale),
        "serve": lambda: bench_serve.run(steps=120 * scale),
        "quant": lambda: bench_quant.run(n=1024, d=16 * scale),
        "roofline": lambda: roofline_table.run(
            ("results_dryrun_16x16.jsonl", "results_dryrun_2x16x16.jsonl")),
    }
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===")
        t0 = time.time()
        rows = _emit(fn())
        path = os.path.join(args.out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# {name} done in {time.time() - t0:.1f}s "
              f"({path})", file=sys.stderr)


if __name__ == "__main__":
    main()
