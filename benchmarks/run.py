"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the scaffold contract), one
section per benchmark. Scale knobs are CI-sized; pass --full for paper-scale.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _emit(rows):
    for r in rows:
        name = r.get("name") or "_".join(
            str(r.get(k)) for k in ("dataset", "algo", "arm", "pulls_per_arm")
            if r.get(k) is not None)
        us = r.get("us_per_call", r.get("sec", 0) and r["sec"] * 1e6)
        derived = r.get("derived") or json.dumps(
            {k: v for k, v in r.items()
             if k not in ("name", "us_per_call", "sec", "dataset", "algo")})
        print(f"{name},{us},{derived!r}")
        sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", default=None,
                    choices=[None, "algorithms", "curves", "correlation",
                             "kernels", "backends", "ragged", "roofline"])
    args = ap.parse_args()
    scale = 2 if args.full else 1

    from benchmarks import (bench_algorithms, bench_backends,
                            bench_correlation, bench_error_curves,
                            bench_kernels, bench_ragged, roofline_table)

    sections = {
        "algorithms": lambda: bench_algorithms.run(
            n=2048 * scale, d=256 * scale, trials=10 * scale),
        "curves": lambda: bench_error_curves.run(
            n=1024 * scale, d=128 * scale, trials=20 * scale),
        "correlation": lambda: bench_correlation.run(
            n=1024 * scale, d=256 * scale),
        "kernels": lambda: bench_kernels.run(),
        "backends": lambda: bench_backends.run(
            grid=((512 * scale, 64 * scale), (1024 * scale, 128 * scale))),
        "ragged": lambda: bench_ragged.run(
            ns=(64, 257, 1024), d=16 * scale),
        "roofline": lambda: roofline_table.run(
            ("results_dryrun_16x16.jsonl", "results_dryrun_2x16x16.jsonl")),
    }
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} ===")
        t0 = time.time()
        _emit(fn())
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
