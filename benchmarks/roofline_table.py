"""§Roofline table generator: reads the dry-run JSONL artifacts and renders
the per-(arch x shape x mesh) roofline rows for EXPERIMENTS.md."""
from __future__ import annotations

import json
import os


def load(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path)]


def render_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | step (s) | useful-FLOP frac | MFU | live GB/chip |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                       f"SKIP: {r['reason'][:40]} | - | - | - | - |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
                       f"| - | - | - | ERROR | - | - | - | - |")
            continue
        live = r["per_device_bytes"]["total_live"] / 1e9
        uf = r.get("useful_flops_frac")
        mfu = r.get("mfu")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} "
            f"| {r['t_collective_s']:.4g} | {r['bottleneck']} "
            f"| {r['step_time_s']:.4g} "
            f"| {uf:.3f} | {mfu if mfu is None else round(mfu, 4)} "
            f"| {live:.1f} |")
    return "\n".join(out)


def run(paths=("results_dryrun_16x16.jsonl",)) -> list[dict]:
    rows = []
    for p in paths:
        for r in load(p):
            if r["status"] != "ok":
                continue
            rows.append({"name": f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                         "us_per_call": round(r["step_time_s"] * 1e6, 1),
                         "derived": f"bot={r['bottleneck']},mfu={r.get('mfu')}"})
    return rows


if __name__ == "__main__":
    import sys
    paths = sys.argv[1:] or ["results_dryrun_16x16.jsonl",
                             "results_dryrun_2x16x16.jsonl"]
    for p in paths:
        print(f"\n## {p}\n")
        print(render_markdown(load(p)))
