"""Distance-backend sweep: reference vs pallas_pairwise vs pallas_fused.

Two tables per (metric, n, d) cell:

* **centrality**: time one round-shaped centrality call (C candidates x R
  references -> (C,) estimates), the engine hot path, per backend; and
* **end-to-end**: ``repro.api.find_medoid`` wall time per backend, asserting all
  backends return the same medoid on the same key (parity is part of the
  benchmark contract, not just the test-suite's).

The ``hbm_block_bytes`` column is the point of the fused path: the bytes the
(C, R) block would occupy in HBM — materialized by reference/pallas_pairwise,
*never allocated* by pallas_fused (its kernels reduce over references inside
VMEM; the only (C,)-sized output leaves the kernel).

On this CPU container the Pallas backends execute in interpret mode, so their
absolute timings are correctness artifacts, not performance (see
bench_kernels.py); the table still demonstrates parity and the memory shape
of each path. On TPU the same sweep is the real roofline comparison.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.api import find_medoid
from repro.core import get_backend, list_backends

_CPU_INTERPRET_NOTE = "interpret-mode timing (correctness only off-TPU)"


def _time(f, *args, reps: int = 3) -> float:
    jax.block_until_ready(f(*args))          # compile + warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6


def _fp32_backends():
    # the quantized backends (repro.quant) are PERTURBED estimators by
    # design — their cells (and parity-at-fp32-answers assertions) live in
    # bench_quant; this section times and cross-checks the exact fp32 ones
    return [b for b in list_backends() if not b.startswith("quant_")]


def run(grid: tuple[tuple[int, int], ...] = ((1024, 128), (2048, 256)),
        metrics: tuple[str, ...] = ("l1", "l2", "sql2", "cosine"),
        refs: int = 64, budget_per_arm: int = 24) -> list[dict]:
    rows = []
    on_tpu = jax.default_backend() == "tpu"
    for n, d in grid:
        key = jax.random.key(n + d)
        data = jax.random.normal(key, (n, d))
        y = data[:refs]
        for metric in metrics:
            for name in _fp32_backends():
                be = get_backend(name)
                cent = jax.jit(be.centrality_sums(metric))
                us = _time(cent, data, y)
                blk = n * refs * 4 if be.materializes_block else 0
                note = "" if (on_tpu or name == "reference") \
                    else f" ({_CPU_INTERPRET_NOTE})"
                rows.append({
                    "name": f"centrality_{metric}_{name}_{n}x{refs}x{d}",
                    "us_per_call": round(us, 1),
                    "derived": f"hbm_block_bytes={blk}{note}",
                })
        # end-to-end parity + timing on one representative metric per cell
        medoids = {}
        for name in _fp32_backends():
            f = lambda x, k: find_medoid(x, k, budget_per_arm=budget_per_arm,
                                         metric="l2", backend=name).medoid
            us = _time(f, data, jax.random.key(7), reps=1)
            medoids[name] = int(f(data, jax.random.key(7)))
            rows.append({"name": f"corr_sh_l2_{name}_{n}x{d}",
                         "us_per_call": round(us, 1),
                         "derived": f"medoid={medoids[name]}"})
        assert len(set(medoids.values())) == 1, \
            f"backend medoid mismatch at n={n}, d={d}: {medoids}"
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']!r}")
