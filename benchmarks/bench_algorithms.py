"""Table 1 analogue: corrSH vs Med-dit vs RAND vs exact.

CI-scale datasets mirroring the paper's three benchmark families (RNA-Seq/ℓ1,
Netflix/cosine, MNIST-zeros/ℓ2). Reports pulls-per-arm, wall time, and error
rate over trials, like the paper's Table 1.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.api import find_medoid
from repro.core import (exact_medoid, hardness_stats, meddit_medoid,
                        rand_medoid, schedule_pulls)
from repro.data.medoid_datasets import DATASETS


def run(n: int = 2048, d: int = 512, trials: int = 20,
        budget_per_arm: int = 24) -> list[dict]:
    rows = []
    for name, (metric, gen) in DATASETS.items():
        data = gen(jax.random.key(0), n, d)
        truth = int(exact_medoid(data, metric))
        hs = hardness_stats(data, metric)

        t0 = time.time()
        for s in range(3):
            exact_medoid(data, metric).block_until_ready()
        t_exact = (time.time() - t0) / 3

        # corrSH
        budget = budget_per_arm * n
        errs = 0
        t0 = time.time()
        for s in range(trials):
            m = find_medoid(data, jax.random.key(s), metric=metric,
                            budget_per_arm=budget_per_arm).medoid
            errs += m != truth
        t_corr = (time.time() - t0) / trials
        rows.append({"dataset": name, "metric": metric, "algo": "corrSH",
                     "pulls_per_arm": schedule_pulls(n, budget) / n,
                     "error": errs / trials, "sec": round(t_corr, 4)})

        # Med-dit (one run per dataset: serial-ish loop is slow on CPU)
        t0 = time.time()
        res = meddit_medoid(data, jax.random.key(0), metric=metric,
                            sigma=float(hs.sigma), batch=64,
                            max_pulls=200 * n)
        jax.block_until_ready((res.medoid, res.pulls))   # timer sees device work
        t_med = time.time() - t0
        rows.append({"dataset": name, "metric": metric, "algo": "meddit",
                     "pulls_per_arm": float(res.pulls) / n,
                     "error": float(int(res.medoid) != truth),
                     "sec": round(t_med, 4)})

        # RAND @ 1000 refs (paper setting, scaled)
        refs = min(1000, n)
        errs = 0
        t0 = time.time()
        for s in range(trials):
            m = int(rand_medoid(data, jax.random.key(s), num_refs=refs,
                                metric=metric))
            errs += m != truth
        t_rand = (time.time() - t0) / trials
        rows.append({"dataset": name, "metric": metric, "algo": "rand",
                     "pulls_per_arm": refs, "error": errs / trials,
                     "sec": round(t_rand, 4)})

        rows.append({"dataset": name, "metric": metric, "algo": "exact",
                     "pulls_per_arm": n, "error": 0.0,
                     "sec": round(t_exact, 4),
                     "h2_over_h2tilde": round(float(hs.h2 / hs.h2_tilde), 2)})
    return rows
