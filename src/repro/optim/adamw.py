"""AdamW with decoupled weight decay, global-norm clipping and f32 master
moments (params may be bf16). No optax dependency — pure pytree transforms."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(f32, params),
                      nu=jax.tree.map(f32, params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
           max_grad_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), \
        {"grad_norm": gnorm}
