"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantization of gradients before the data-parallel reduction,
with a persistent error-feedback buffer so the quantization error is carried
into the next step instead of lost (Karimireddy et al., 2019). Under pjit the
quantize -> psum -> dequantize pattern reduces the all-reduce payload 4x
(f32) / 2x (bf16); the error buffer keeps convergence unbiased in the long
run. Toggled per-config; measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # quantization block (per-block scale)


class EFState(NamedTuple):
    error: Any   # pytree of f32 residuals, same shapes as grads


def init_error_feedback(params) -> EFState:
    return EFState(error=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-wise symmetric int8 quantization. Returns (q, scales)."""
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale[:, 0]


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_decompress(g: jnp.ndarray) -> jnp.ndarray:
    """Round-trip int8 quantization (the lossy channel)."""
    q, s = _quantize(g.astype(jnp.float32))
    return _dequantize(q, s, g.shape)


def apply_error_feedback(grads, ef: EFState) -> Tuple[Any, EFState]:
    """Quantize (grads + carried error); carry the new residual."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        sent = compress_decompress(gf)
        return sent.astype(g.dtype), gf - sent

    out = jax.tree.map(one, grads, ef.error)
    sent = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return sent, EFState(error=err)
