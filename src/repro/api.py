"""The stable public facade of the repro medoid system.

Every workload the repo serves — single-query medoid identification
(the paper's Algorithm 1), batched and ragged multi-query serving,
distributed execution, baseline algorithms, and bandit k-medoids
clustering — enters through the four functions here:

    from repro.api import (MedoidConfig, KMedoidsConfig, find_medoid,
                           find_medoids_batch, find_medoids_ragged, kmedoids)

    res = find_medoid(data, key)                          # MedoidResult
    res = find_medoid(data, key, backend="pallas_fused", budget_per_arm=32)
    meds = find_medoids_batch(batch, key)                 # (B,) indices
    meds = find_medoids_ragged([q1, q2, q3], key=key)     # any sizes
    clust = kmedoids(data, k=8, key=key)                  # KMedoidsResult
    live = maintain_medoid(data)                          # MaintainedMedoid
    live.insert(x); live.delete(slot); live.query()       # mutable corpus

Configuration is a frozen dataclass (:class:`MedoidConfig` /
:class:`KMedoidsConfig`); every entry point also accepts the config fields
directly as keyword overrides (``find_medoid(x, key, metric="l1")`` is
``find_medoid(x, key, config=MedoidConfig(metric="l1"))``).

All of these are thin adapters over ONE engine —
:func:`repro.engine.run_halving`, the estimator-parameterized correlated-SH
round loop — so masking, bucketed batching, fused Pallas paths, the on-chip
top-k epilogue, and the compile odometer apply uniformly. ``algo=`` swaps
the algorithm itself (``corr_sh`` | ``meddit`` | ``rand`` | ``exact``)
behind the same call, and ``mesh=`` routes ``find_medoid`` through the
shard_map distributed engines. The pre-facade entry points
(``corr_sh_medoid*``, ``bandit_kmedoids``) still work as deprecated shims.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bucketing import DEFAULT_MIN_BUCKET, pack_queries
from repro.core.corr_sh import _batch_impl, _medoid_impl, ragged_medoids
from repro.core.exact import exact_medoid
from repro.core.meddit import meddit_medoid
from repro.core.rand import rand_medoid
from repro.engine import round_schedule, stop_round
from repro.obs import telemetry_to_host

ALGOS = ("corr_sh", "meddit", "rand", "exact")

__all__ = [
    "ALGOS", "KMedoidsConfig", "MedoidConfig", "MedoidResult", "find_medoid",
    "find_medoids_batch", "find_medoids_ragged", "kmedoids",
    "maintain_medoid",
]


# --------------------------------- configs ----------------------------------

@dataclass(frozen=True)
class MedoidConfig:
    """How a medoid query runs. ``budget_per_arm`` scales the paper's pull
    budget (``budget = budget_per_arm * n``; for ragged traffic, ``n`` is the
    power-of-two bucket). ``algo`` selects the algorithm behind the facade:
    ``corr_sh`` (the paper; the only one with batch/ragged modes), the
    ``meddit`` UCB baseline, the ``rand`` non-adaptive baseline
    (``budget_per_arm`` references), or the ``exact`` O(n^2) oracle.

    ``telemetry`` additionally returns the fixed-shape per-round trace of
    :mod:`repro.obs.telemetry` (host numpy, one row per executed round) —
    same single dispatch, bit-identical answers; ``corr_sh`` only.

    ``precision`` selects the distance arithmetic: ``"fp32"`` (default,
    bit-identical to every previous release), or the quantized ``"bf16"`` /
    ``"int8"`` paths of :mod:`repro.quant` — halving runs margin-widened
    against the quantization error model (``quant_error_model``: measured
    ``"probe"`` or certified-worst-case ``"analytic"``) and the finalists
    are re-verified in exact fp32; a run whose widened margins overflowed
    capacity falls back to a same-key fp32 re-run, so answers are exact
    either way. ``corr_sh`` only."""
    metric: str = "l2"
    backend: str = "reference"
    budget_per_arm: int = 24
    algo: str = "corr_sh"
    min_bucket: int = DEFAULT_MIN_BUCKET
    seed: int = 0          # key when the caller passes none
    telemetry: bool = False
    precision: str = "fp32"
    quant_error_model: str = "probe"


@dataclass(frozen=True)
class KMedoidsConfig:
    """How a k-medoids clustering job runs (BUILD -> ragged per-cluster
    refinement -> bandit SWAP, all on the unified engine)."""
    metric: str = "l2"
    backend: str = "reference"
    build_budget_per_arm: int = 16
    swap_budget_per_arm: int = 16
    refine_budget_per_arm: int = 20
    refine_sweeps: int = 1
    max_swap_rounds: int = 8
    min_bucket: int = DEFAULT_MIN_BUCKET
    seed: int = 0


@dataclass(frozen=True)
class MedoidResult:
    """One answered medoid query: the winning index plus exact (scheduled)
    pull accounting and the round plan that produced it.

    ``precision`` echoes the config. ``verified`` is ``None`` for fp32 runs;
    for quantized runs it is ``True`` when the widened margins held all the
    way down (the quantized answer carries the exact-fp32-finalist
    certificate) and ``False`` when capacity overflowed — the reported
    ``medoid`` then came from the same-key fp32 fallback re-run and is exact
    regardless. ``hardness`` (telemetry runs only) carries the instance
    hardness stats of :mod:`repro.core.hardness` — Δ₂ gap, σ spread, and
    the paper's H₂/H̃₂ hardness sums."""
    medoid: int
    pulls: int
    n: int
    algo: str
    metric: str
    backend: str
    rounds: tuple = ()     # (survivors, num_refs) per executed round
    telemetry: Optional[dict] = None   # per-round trace (host numpy) when
    #                                    MedoidConfig.telemetry is set
    precision: str = "fp32"
    verified: Optional[bool] = None
    hardness: Optional[dict] = None


def _resolve(config, overrides, cls):
    cfg = config if config is not None else cls()
    if not isinstance(cfg, cls):
        raise TypeError(f"config must be a {cls.__name__}, got {type(cfg)!r}")
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def _key_of(key, cfg):
    return jax.random.key(cfg.seed) if key is None else key


# ------------------------------- single query -------------------------------

def find_medoid(data: jnp.ndarray, key: Optional[jax.Array] = None, *,
                config: Optional[MedoidConfig] = None, mesh=None,
                distributed_impl: str = "v2", **overrides) -> MedoidResult:
    """Find the medoid of ``data (n, d)``.

    The default (``algo="corr_sh"``) runs the paper's correlated sequential
    halving through the unified engine on the configured distance backend.
    Pass ``mesh=`` (a ``jax.sharding.Mesh``; rows of ``data`` sharded over
    all its axes) to run the distributed shard_map engine instead
    (``distributed_impl="v2"`` communication-optimal, ``"v1"`` replicated).
    """
    cfg = _resolve(config, overrides, MedoidConfig)
    if cfg.algo not in ALGOS:
        raise ValueError(f"unknown algo {cfg.algo!r}; one of {ALGOS}")
    data = jnp.asarray(data)
    if data.ndim != 2:
        raise ValueError(f"expected (n, d) data, got shape {data.shape}")
    n = int(data.shape[0])
    key = _key_of(key, cfg)
    budget = cfg.budget_per_arm * n

    if cfg.telemetry and (cfg.algo != "corr_sh" or mesh is not None):
        raise ValueError("telemetry=True requires algo='corr_sh' without "
                         "mesh= (only the engine round loop is instrumented)")
    if cfg.precision != "fp32":
        from repro import quant
        quant.check_precision(cfg.precision)
        if cfg.algo != "corr_sh" or mesh is not None:
            raise ValueError("precision != 'fp32' requires algo='corr_sh' "
                             "without mesh= (only the engine round loop has "
                             "the widened-margin + verification path)")

    if mesh is not None:
        if cfg.algo != "corr_sh":
            raise ValueError(f"mesh= requires algo='corr_sh', got {cfg.algo!r}")
        from repro.core.distributed import distributed_corr_sh
        from repro.core.distributed_v2 import distributed_corr_sh_v2
        impls = {"v1": distributed_corr_sh, "v2": distributed_corr_sh_v2}
        try:
            fn = impls[distributed_impl]
        except KeyError:
            raise ValueError(f"distributed_impl must be one of "
                             f"{sorted(impls)}, got {distributed_impl!r}"
                             ) from None
        medoid = int(fn(data, key, mesh, budget=budget, metric=cfg.metric,
                        backend=cfg.backend))
        rounds = round_schedule(n, budget)
        return MedoidResult(medoid=medoid,
                            pulls=sum(r.pulls for r in rounds), n=n,
                            algo=f"corr_sh_distributed_{distributed_impl}",
                            metric=cfg.metric, backend=cfg.backend,
                            rounds=tuple((r.survivors, r.num_refs)
                                         for r in rounds))

    if cfg.algo == "exact":
        return MedoidResult(medoid=int(exact_medoid(data, cfg.metric)),
                            pulls=n * n, n=n, algo="exact",
                            metric=cfg.metric, backend=cfg.backend)
    if cfg.algo == "rand":
        refs = max(1, cfg.budget_per_arm)
        m = rand_medoid(data, key, num_refs=refs, metric=cfg.metric)
        return MedoidResult(medoid=int(m), pulls=n * refs, n=n, algo="rand",
                            metric=cfg.metric, backend=cfg.backend)
    if cfg.algo == "meddit":
        res = meddit_medoid(data, key, metric=cfg.metric)
        return MedoidResult(medoid=int(res.medoid), pulls=int(res.pulls),
                            n=n, algo="meddit", metric=cfg.metric,
                            backend=cfg.backend)

    if n == 1:
        tel = None
        if cfg.telemetry:
            from repro.obs import telemetry as obs_telemetry
            tel = telemetry_to_host(obs_telemetry.empty())
        return MedoidResult(medoid=0, pulls=0, n=1, algo="corr_sh",
                            metric=cfg.metric, backend=cfg.backend,
                            telemetry=tel, precision=cfg.precision,
                            verified=None if cfg.precision == "fp32"
                            else True)
    out = _medoid_impl(data, key, budget=budget, metric=cfg.metric,
                       backend=cfg.backend, telemetry=cfg.telemetry,
                       precision=cfg.precision,
                       error_model=cfg.quant_error_model)
    rounds = round_schedule(n, budget)
    executed = rounds[: stop_round(rounds) + 1]
    pulls = sum(r.pulls for r in executed)
    tel = None
    verified = None
    if cfg.precision == "fp32":
        if cfg.telemetry:
            out, tel = out
        medoid = int(out)
    else:
        from repro import quant
        if cfg.telemetry:
            out, ver, tel = out
        else:
            out, ver = out
        verified = bool(ver)
        pulls += quant.verify_pulls(n, rounds)
        if verified:
            medoid = int(out)
        else:
            # Widened margins overflowed their buffers somewhere — the
            # quantized answer lost its certificate. Re-run in fp32 with the
            # SAME key: identical draws, exact estimates, exact answer (and
            # the exact telemetry replaces the quantized trace).
            fout = _medoid_impl(data, key, budget=budget, metric=cfg.metric,
                                backend=cfg.backend, telemetry=cfg.telemetry)
            if cfg.telemetry:
                fout, tel = fout
            medoid = int(fout)
            pulls += sum(r.pulls for r in executed)
    if tel is not None:
        tel = telemetry_to_host(tel)
    hardness = None
    if cfg.telemetry:
        from repro.core.hardness import hardness_stats
        hs = hardness_stats(data, metric=cfg.metric)
        hardness = {"delta2": float(hs.delta[1]), "sigma": float(hs.sigma),
                    "h2": float(hs.h2), "h2_tilde": float(hs.h2_tilde)}
    return MedoidResult(medoid=medoid, pulls=pulls, n=n,
                        algo="corr_sh", metric=cfg.metric,
                        backend=cfg.backend,
                        rounds=tuple((r.survivors, r.num_refs)
                                     for r in executed),
                        telemetry=tel, precision=cfg.precision,
                        verified=verified, hardness=hardness)


# -------------------------------- multi query -------------------------------

def find_medoids_batch(data: jnp.ndarray, key: Optional[jax.Array] = None, *,
                       config: Optional[MedoidConfig] = None,
                       **overrides) -> jnp.ndarray:
    """Answer a ``(B, n, d)`` batch of independent medoid queries in one XLA
    dispatch (one shared static schedule, per-query reference draws).
    Returns the ``(B,)`` int32 medoid indices — or, with
    ``telemetry=True``, ``(indices, telemetry)`` where the telemetry leaves
    are host ``(B, R)`` arrays (one row per query per executed round)."""
    cfg = _resolve(config, overrides, MedoidConfig)
    if cfg.algo != "corr_sh":
        raise ValueError(f"batched mode requires algo='corr_sh', "
                         f"got {cfg.algo!r}")
    data = jnp.asarray(data)
    n = int(data.shape[1]) if data.ndim == 3 else 0
    key = _key_of(key, cfg)
    out = _batch_impl(data, key,
                      budget=cfg.budget_per_arm * max(n, 1),
                      metric=cfg.metric, backend=cfg.backend,
                      telemetry=cfg.telemetry, precision=cfg.precision,
                      error_model=cfg.quant_error_model)
    tel = None
    if cfg.precision == "fp32":
        if cfg.telemetry:
            medoids, tel = out
        else:
            medoids = out
    else:
        if cfg.telemetry:
            medoids, verified, tel = out
        else:
            medoids, verified = out
        if not bool(jnp.all(verified)):
            # Unverified queries fall back to the exact same-key fp32 batch
            # (one extra dispatch, shared by every overflowed query).
            fout = _batch_impl(data, key,
                               budget=cfg.budget_per_arm * max(n, 1),
                               metric=cfg.metric, backend=cfg.backend,
                               telemetry=False)
            medoids = jnp.where(verified, medoids, fout)
    if tel is not None:
        return medoids, telemetry_to_host(tel)
    return medoids


def find_medoids_ragged(data, lengths=None,
                        key: Optional[jax.Array] = None, *,
                        config: Optional[MedoidConfig] = None,
                        **overrides) -> jnp.ndarray:
    """Answer mixed-size medoid queries through one bucketed XLA program.

    Accepts either a pre-packed ``(B, n_max, d)`` array with per-query
    ``lengths (B,)``, or simply a list of ``(n_i, d)`` arrays (packed via
    :func:`repro.core.bucketing.pack_queries`). The bucket's budget is
    ``budget_per_arm * n_bucket``; padding is masked inside every round, and
    a query filling its bucket is bit-identical to the single-query path.
    Returns the ``(B,)`` int32 medoid indices (each < its query's length) —
    or ``(indices, telemetry)`` with ``telemetry=True`` (host ``(B, R)``
    leaves; schedule columns are the bucket's).
    """
    cfg = _resolve(config, overrides, MedoidConfig)
    if cfg.algo != "corr_sh":
        raise ValueError(f"ragged mode requires algo='corr_sh', "
                         f"got {cfg.algo!r}")
    donate = False
    if isinstance(data, (list, tuple)):
        if lengths is not None:
            raise ValueError("pass lengths only with pre-packed array data")
        data, lengths = pack_queries(list(data), min_bucket=cfg.min_bucket)
        # the facade packed this buffer itself and never touches it again —
        # donate it to the program. User-passed arrays are never donated.
        donate = True
    elif lengths is None:
        raise ValueError("pre-packed array data needs explicit lengths")
    data = jnp.asarray(data)
    n_bucket = int(data.shape[1]) if data.ndim == 3 else 1
    from repro.core.bucketing import bucket_n
    n_bucket = bucket_n(n_bucket, cfg.min_bucket)
    key = _key_of(key, cfg)
    # A quantized run may need the buffer again for the fp32 fallback, so
    # only the fallback dispatch (the buffer's last use) may take it.
    out = ragged_medoids(data, lengths, key,
                         budget=cfg.budget_per_arm * n_bucket,
                         metric=cfg.metric, backend=cfg.backend,
                         min_bucket=cfg.min_bucket,
                         donate=donate and cfg.precision == "fp32",
                         telemetry=cfg.telemetry, precision=cfg.precision,
                         error_model=cfg.quant_error_model)
    tel = None
    if cfg.precision == "fp32":
        if cfg.telemetry:
            medoids, tel = out
        else:
            medoids = out
    else:
        if cfg.telemetry:
            medoids, verified, tel = out
        else:
            medoids, verified = out
        if not bool(jnp.all(verified)):
            fout = ragged_medoids(data, lengths, key,
                                  budget=cfg.budget_per_arm * n_bucket,
                                  metric=cfg.metric, backend=cfg.backend,
                                  min_bucket=cfg.min_bucket, donate=donate,
                                  telemetry=False)
            medoids = jnp.where(verified, medoids, fout)
    if tel is not None:
        return medoids, telemetry_to_host(tel)
    return medoids


# ------------------------------ mutable corpus ------------------------------

def maintain_medoid(data=None, *, d: Optional[int] = None,
                    config: Optional[MedoidConfig] = None, **overrides):
    """Build a live, incrementally-maintained medoid over a mutable corpus.

    Returns a :class:`repro.serve.MaintainedMedoid`: ``insert(x)`` /
    ``delete(slot)`` mutate the corpus at O(n) distance evaluations each
    (one exact n-vector updates every live point's centrality), ``query()``
    serves the maintained answer for the current corpus version for free,
    and only a dethroned (or deleted) incumbent triggers a full
    correlated-SH re-run — dispatched through the same cached programs as
    :func:`find_medoids_ragged`, keyed by corpus version for bit-exact
    reproducibility. Pass ``data (n, d)`` to bootstrap from an existing
    corpus, or ``d=`` alone to start empty. Config fields (``metric``,
    ``backend``, ``budget_per_arm``, ``min_bucket``, ``seed``) mean what
    they mean everywhere else in this facade.
    """
    from repro.serve import CorpusStore, MaintainedMedoid

    cfg = _resolve(config, overrides, MedoidConfig)
    if cfg.algo != "corr_sh":
        raise ValueError(f"maintain_medoid requires algo='corr_sh', "
                         f"got {cfg.algo!r}")
    if data is not None:
        store = CorpusStore.from_points(jnp.asarray(data), metric=cfg.metric,
                                        backend=cfg.backend,
                                        min_bucket=cfg.min_bucket,
                                        precision=cfg.precision)
    elif d is not None:
        store = CorpusStore(d, metric=cfg.metric, backend=cfg.backend,
                            min_bucket=cfg.min_bucket,
                            precision=cfg.precision)
    else:
        raise ValueError("pass data (n, d) or d= to start an empty corpus")
    return MaintainedMedoid(store, budget_per_arm=cfg.budget_per_arm,
                            seed=cfg.seed)


# -------------------------------- clustering --------------------------------

def kmedoids(data: jnp.ndarray, k: int, key: Optional[jax.Array] = None, *,
             config: Optional[KMedoidsConfig] = None, refiner=None,
             **overrides):
    """Bandit k-medoids (BUILD -> ragged refinement -> bandit SWAP) on the
    unified engine. Returns a :class:`repro.cluster.KMedoidsResult` (point
    indices, labels, cost, exact pull accounting). ``refiner`` overrides how
    the per-cluster subproblems are answered — see
    :func:`repro.cluster.service.kmedoids_via_service` for the
    continuous-batching route."""
    from repro.cluster.kmedoids import _kmedoids_impl

    cfg = _resolve(config, overrides, KMedoidsConfig)
    return _kmedoids_impl(
        data, k, _key_of(key, cfg), metric=cfg.metric, backend=cfg.backend,
        build_budget_per_arm=cfg.build_budget_per_arm,
        swap_budget_per_arm=cfg.swap_budget_per_arm,
        refine_budget_per_arm=cfg.refine_budget_per_arm,
        refine_sweeps=cfg.refine_sweeps,
        max_swap_rounds=cfg.max_swap_rounds,
        min_bucket=cfg.min_bucket, refiner=refiner)
