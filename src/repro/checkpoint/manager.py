"""Checkpoint manager: atomic commits, rotation, auto-resume, elastic reshard.

Layout:  <dir>/step_<N>/   arrays.npz   (flattened pytree leaves)
                           META.json    (treedef paths, step, mesh shape)
         <dir>/step_<N>.tmp...          (staging; atomic rename to commit)

Fault-tolerance properties:
  * atomic commit: writers stage into a tmp dir and `os.rename` — a crashed
    writer never corrupts the latest checkpoint;
  * rotation keeps the newest K checkpoints (plus optional keep-every);
  * `latest_step` / `restore` pick up the newest *committed* checkpoint, so a
    restarted job always resumes from a consistent state;
  * elastic reshard: arrays are saved *unsharded by logical path*; on restore
    they are device_put against whatever sharding the new mesh prescribes, so
    a 512-chip checkpoint restores onto 256 chips (or 1 CPU) unchanged.

At true fleet scale the npz writer is replaced by a per-shard writer behind
the same interface; the commit protocol (stage + rename + MANIFEST) is the
load-bearing part and is what the tests exercise.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = jnp.asarray(leaf)
        if arr.dtype == jnp.bfloat16:   # npz has no bf16: store f32 (lossless)
            arr = arr.astype(jnp.float32)
        flat[key] = np.asarray(arr)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    """Atomically write checkpoint for `step`; rotate old ones."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in flat.items()})
    meta = {"step": step, "keys": sorted(flat), "extra": extra or {}}
    with open(os.path.join(tmp, "META.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)         # atomic commit
    _rotate(ckpt_dir, keep)
    return final


def _rotate(ckpt_dir: str, keep: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "META.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching pytree of NamedSharding
    for elastic placement on the current mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "META.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))

    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), shard in zip(paths, shard_leaves):
        key = "/".join(_path_str(p) for p in path)
        arr = data[key]
        want_dtype = leaf.dtype
        a = jnp.asarray(arr).astype(want_dtype)
        if shard is not None:
            a = jax.device_put(a, shard)
        leaves.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, meta
