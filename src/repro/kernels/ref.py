"""Pure-jnp oracles for the Pallas kernels (ground truth in tests/benchmarks)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.distances import (
    pairwise_cosine,
    pairwise_l1,
    pairwise_l2,
    pairwise_sql2,
)


def ref_dot_pairwise(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return x.astype(jnp.float32) @ y.astype(jnp.float32).T


def ref_l1_pairwise(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return pairwise_l1(x, y)


def ref_l1_centrality(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(pairwise_l1(x, y), axis=1, keepdims=True)


def ref_pairwise(metric: str, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return {
        "l1": pairwise_l1,
        "l2": pairwise_l2,
        "sql2": pairwise_sql2,
        "cosine": pairwise_cosine,
    }[metric](x, y)
