"""Pallas TPU kernels for the medoid engine's hot loop.

The paper's per-round hot spot is the rectangular distance block
``D[c, j] = d(X[S_r][c], X[J_r][j])`` plus its row-mean. On TPU we split the
metrics into two kernel families:

* **dot kernel** (MXU path): pairwise inner products ``G = X @ Y^T`` with f32
  accumulation. ℓ2 / squared-ℓ2 / cosine reduce to ``G`` plus O(nd) row norms
  computed outside the kernel (Gram trick), so the inner loop runs on the
  128x128 systolic array at full rate.

* **ℓ1 kernels** (VPU path): ``sum |x - y|`` has no matmul form. The kernel
  tiles ``(BC, BD) x (BR, BD)`` into VMEM and accumulates f32 partial sums,
  chunking the d-axis inside the block to bound the broadcast intermediate
  (BC x BR x CHUNK). Two variants:
    - ``l1_pairwise``  -> (C, R) distance matrix
    - ``l1_centrality``-> fused row-sum (C,): never materializes (C, R) in HBM,
      which is the memory-roofline win for large reference sets.

Grid layout: (i, j, k) with k (the d-axis) innermost so each output tile is
revisited across k steps and accumulated in place (standard Pallas reduction
pattern); the fused centrality kernel also folds j into the accumulation.

All wrappers in ``ops.py`` pad shapes to block multiples; padded d-columns are
zeros (contribute 0 to every metric), padded candidate rows are sliced off,
and padded reference rows are masked *inside* the kernels via the global
column index (closured static true size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: MXU-aligned (multiples of 128 in the matmul dims). The ℓ1 VPU
# kernel keeps the same tile footprint but chunks d to bound VMEM.
BC = 128   # candidate rows per tile
BR = 128   # reference rows per tile
BD = 256   # d-axis slab per grid step
L1_CHUNK = 16  # d-chunk inside the ℓ1 kernel: BC*BR*CHUNK*4B = 1 MiB VMEM


# --------------------------------------------------------------------------
# dot kernel (MXU): G[c, r] = sum_d X[c, d] * Y[r, d]
# --------------------------------------------------------------------------

def _dot_kernel(x_ref, y_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    y = y_ref[...]
    o_ref[...] += jax.lax.dot_general(
        x, y, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dot_pairwise(x: jnp.ndarray, y: jnp.ndarray, *,
                 interpret: bool = False) -> jnp.ndarray:
    """X: (C, d), Y: (R, d) — C, R, d already padded to block multiples."""
    c, d = x.shape
    r, _ = y.shape
    grid = (c // BC, r // BR, d // BD)
    return pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((BC, BR), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, r), jnp.float32),
        interpret=interpret,
    )(x, y)


# --------------------------------------------------------------------------
# ℓ1 pairwise kernel (VPU): D[c, r] = sum_d |X[c, d] - Y[r, d]|
# --------------------------------------------------------------------------

def _l1_pairwise_kernel(x_ref, y_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)   # (BC, BD)
    y = y_ref[...].astype(jnp.float32)   # (BR, BD)
    acc = jnp.zeros_like(o_ref)
    for c0 in range(0, BD, L1_CHUNK):    # static unroll: bound VMEM intermediate
        xs = x[:, c0:c0 + L1_CHUNK]
        ys = y[:, c0:c0 + L1_CHUNK]
        acc += jnp.sum(jnp.abs(xs[:, None, :] - ys[None, :, :]), axis=-1)
    o_ref[...] += acc


def l1_pairwise(x: jnp.ndarray, y: jnp.ndarray, *,
                interpret: bool = False) -> jnp.ndarray:
    c, d = x.shape
    r, _ = y.shape
    grid = (c // BC, r // BR, d // BD)
    return pl.pallas_call(
        _l1_pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((BC, BR), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, r), jnp.float32),
        interpret=interpret,
    )(x, y)


# --------------------------------------------------------------------------
# fused ℓ1 centrality kernel: S[c] = sum_{r < r_true} sum_d |X[c,d] - Y[r,d]|
# Never materializes the (C, R) matrix in HBM.
# --------------------------------------------------------------------------

def _l1_centrality_kernel(x_ref, y_ref, o_ref, *, r_true: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)   # (BC, BD)
    y = y_ref[...].astype(jnp.float32)   # (BR, BD)
    # mask padded reference rows by global row index
    col = j * BR + jax.lax.broadcasted_iota(jnp.int32, (BR, 1), 0)
    mask = (col < r_true).astype(jnp.float32)          # (BR, 1)
    acc = jnp.zeros_like(o_ref)                        # (BC, 1)
    for c0 in range(0, BD, L1_CHUNK):
        xs = x[:, c0:c0 + L1_CHUNK]
        ys = y[:, c0:c0 + L1_CHUNK] * mask             # zero padded rows
        a = jnp.abs(xs[:, None, :] - ys[None, :, :])   # (BC, BR, CHUNK)
        # |x - 0| on padded rows must not count: mask the whole (r) slice
        a = a * mask[None, :, :]
        acc += jnp.sum(a, axis=(1, 2), keepdims=False)[:, None]
    o_ref[...] += acc


def l1_centrality(x: jnp.ndarray, y: jnp.ndarray, r_true: int, *,
                  interpret: bool = False) -> jnp.ndarray:
    """Row sums of |X - Y| distances over the first ``r_true`` rows of Y.

    x: (C, d), y: (R, d) padded; returns (C, 1) f32 sums (not yet divided).
    """
    c, d = x.shape
    r, _ = y.shape
    grid = (c // BC, r // BR, d // BD)
    kern = functools.partial(_l1_centrality_kernel, r_true=r_true)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((BC, 1), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 1), jnp.float32),
        interpret=interpret,
    )(x, y)
