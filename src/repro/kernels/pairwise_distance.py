"""Pallas TPU kernels for the medoid engine's hot loop.

The paper's per-round hot spot is the rectangular distance block
``D[c, j] = d(X[S_r][c], X[J_r][j])`` plus its row-mean. On TPU we split the
metrics into two kernel families:

* **dot kernel** (MXU path): pairwise inner products ``G = X @ Y^T`` with f32
  accumulation. ℓ2 / squared-ℓ2 / cosine reduce to ``G`` plus O(nd) row norms
  computed outside the kernel (Gram trick), so the inner loop runs on the
  128x128 systolic array at full rate.

* **ℓ1 kernels** (VPU path): ``sum |x - y|`` has no matmul form. The kernel
  tiles ``(BC, BD) x (BR, BD)`` into VMEM and accumulates f32 partial sums,
  chunking the d-axis inside the block to bound the broadcast intermediate
  (BC x BR x CHUNK). Two variants:
    - ``l1_pairwise``  -> (C, R) distance matrix
    - ``l1_centrality``-> fused row-sum (C,): never materializes (C, R) in HBM,
      which is the memory-roofline win for large reference sets.

Grid layout: (i, j, k) with k (the d-axis) innermost so each output tile is
revisited across k steps and accumulated in place (standard Pallas reduction
pattern); the fused centrality kernel also folds j into the accumulation.

All wrappers in ``ops.py`` pad shapes to block multiples; padded d-columns are
zeros (contribute 0 to every metric), padded candidate rows are sliced off,
and padded reference rows are masked *inside* the kernels via a per-reference
validity mask streamed in as a kernel input. The mask generalizes the old
static ``col < r_true`` predicate: the ragged multi-query engine reuses the
same kernels with arbitrary validity patterns (padded arms of short queries),
while the dense wrappers pass the prefix mask and get bit-identical results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Block sizes: MXU-aligned (multiples of 128 in the matmul dims). The ℓ1 VPU
# kernel keeps the same tile footprint but chunks d to bound VMEM.
BC = 128   # candidate rows per tile
BR = 128   # reference rows per tile
BD = 256   # d-axis slab per grid step
L1_CHUNK = 16  # d-chunk inside the ℓ1 kernel: BC*BR*CHUNK*4B = 1 MiB VMEM


# --------------------------------------------------------------------------
# dot kernel (MXU): G[c, r] = sum_d X[c, d] * Y[r, d]
# --------------------------------------------------------------------------

def _dot_kernel(x_ref, y_ref, o_ref, *, compute_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # In-kernel quantization cast (the VMEM tile is rounded, never the HBM
    # copy): bf16 multiplies run the MXU at its doubled rate; accumulation
    # stays f32 via preferred_element_type either way.
    x = x_ref[...].astype(compute_dtype)
    y = y_ref[...].astype(compute_dtype)
    o_ref[...] += jax.lax.dot_general(
        x, y, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dot_pairwise(x: jnp.ndarray, y: jnp.ndarray, *,
                 compute_dtype: str = "float32",
                 interpret: bool = False) -> jnp.ndarray:
    """X: (C, d), Y: (R, d) — C, R, d already padded to block multiples.
    ``compute_dtype`` sets the multiply precision (f32 accumulation always).
    """
    c, d = x.shape
    r, _ = y.shape
    grid = (c // BC, r // BR, d // BD)
    kern = functools.partial(_dot_kernel,
                             compute_dtype=jnp.dtype(compute_dtype))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((BC, BR), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, r), jnp.float32),
        interpret=interpret,
    )(x, y)


# --------------------------------------------------------------------------
# ℓ1 pairwise kernel (VPU): D[c, r] = sum_d |X[c, d] - Y[r, d]|
# --------------------------------------------------------------------------

def _l1_pairwise_kernel(x_ref, y_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)   # (BC, BD)
    y = y_ref[...].astype(jnp.float32)   # (BR, BD)
    acc = jnp.zeros_like(o_ref)
    for c0 in range(0, BD, L1_CHUNK):    # static unroll: bound VMEM intermediate
        xs = x[:, c0:c0 + L1_CHUNK]
        ys = y[:, c0:c0 + L1_CHUNK]
        acc += jnp.sum(jnp.abs(xs[:, None, :] - ys[None, :, :]), axis=-1)
    o_ref[...] += acc


def l1_pairwise(x: jnp.ndarray, y: jnp.ndarray, *,
                interpret: bool = False) -> jnp.ndarray:
    c, d = x.shape
    r, _ = y.shape
    grid = (c // BC, r // BR, d // BD)
    return pl.pallas_call(
        _l1_pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((BC, BR), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, r), jnp.float32),
        interpret=interpret,
    )(x, y)


# --------------------------------------------------------------------------
# fused ℓ1 centrality kernel: S[c] = sum_{r valid} sum_d |X[c,d] - Y[r,d]|
# Never materializes the (C, R) matrix in HBM. Validity is a streamed (R, 1)
# f32 mask (1.0 = count this reference), which covers both block padding and
# the ragged engine's invalid (padded-arm) references.
# --------------------------------------------------------------------------

def _l1_centrality_kernel(x_ref, y_ref, m_ref, o_ref):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)   # (BC, BD)
    y = y_ref[...].astype(jnp.float32)   # (BR, BD)
    mask = m_ref[...]                    # (BR, 1) validity of this ref tile
    acc = jnp.zeros_like(o_ref)          # (BC, 1)
    for c0 in range(0, BD, L1_CHUNK):
        xs = x[:, c0:c0 + L1_CHUNK]
        ys = y[:, c0:c0 + L1_CHUNK]
        a = jnp.abs(xs[:, None, :] - ys[None, :, :])   # (BC, BR, CHUNK)
        # invalid reference rows must not count: mask the whole (r) slice
        a = a * mask[None, :, :]
        acc += jnp.sum(a, axis=(1, 2), keepdims=False)[:, None]
    o_ref[...] += acc


def l1_centrality(x: jnp.ndarray, y: jnp.ndarray, r_true: int, *,
                  ref_mask: jnp.ndarray | None = None,
                  interpret: bool = False) -> jnp.ndarray:
    """Row sums of |X - Y| distances over the valid rows of Y.

    x: (C, d), y: (R, d) padded; returns (C, 1) f32 sums (not yet divided).
    By default the first ``r_true`` rows are valid; ``ref_mask`` (any shape
    broadcastable to (R,), nonzero = valid, already combined with the padding
    prefix by the caller or here) overrides the prefix predicate.
    """
    c, d = x.shape
    r, _ = y.shape
    if ref_mask is None:
        mask = (jnp.arange(r) < r_true).astype(jnp.float32)
    else:
        mask = ref_mask.reshape(-1).astype(jnp.float32)
        mask = mask * (jnp.arange(r) < r_true).astype(jnp.float32)
    grid = (c // BC, r // BR, d // BD)
    return pl.pallas_call(
        _l1_centrality_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
            pl.BlockSpec((BR, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BC, 1), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 1), jnp.float32),
        interpret=interpret,
    )(x, y, mask.reshape(r, 1))


# --------------------------------------------------------------------------
# fused dot-centrality kernel (MXU): S[c] = sum_{r valid} d(X[c], Y[r])
# for the Gram-trick metrics. The (BC, BR) distance tile lives only in a VMEM
# scratch accumulator — the (C, R) block is never materialized in HBM, which
# makes every metric's round memory-roofline-optimal, not just ℓ1.
#
# The d-axis (grid dim k, innermost) accumulates raw inner products into the
# scratch tile; at the last k step the metric's elementwise transform
# (sql2 / l2 / cosine) is applied to the *complete* Gram tile — sqrt does not
# commute with the d-reduction, hence the scratch carry — invalid reference
# rows (block padding or ragged-query padded arms) are zeroed by the streamed
# (1, R) validity mask, and the row-sum folds into o_ref.
# --------------------------------------------------------------------------

def _dot_centrality_kernel(x_ref, y_ref, xn_ref, yn_ref, m_ref, o_ref,
                           acc_ref, *, metric: str, nk: int, compute_dtype):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((j == 0) & (k == 0))
    def _init_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # In-kernel quantization cast (see _dot_kernel); norms, the metric
    # epilogue, and the accumulator stay f32.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(compute_dtype), y_ref[...].astype(compute_dtype),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        g = acc_ref[...]                                   # (BC, BR) complete
        if metric == "cosine":
            # inputs pre-normalized outside: distance is 1 - <x̂, ŷ>
            v = 1.0 - g
        else:
            sq = jnp.maximum(xn_ref[...] + yn_ref[...] - 2.0 * g, 0.0)
            v = jnp.sqrt(sq) if metric == "l2" else sq
        v = v * m_ref[...]                                 # mask invalid refs
        o_ref[...] += jnp.sum(v, axis=1, keepdims=True)    # (BC, 1)


def dot_centrality(x: jnp.ndarray, y: jnp.ndarray, xn2: jnp.ndarray,
                   yn2: jnp.ndarray, r_true: int, *, metric: str,
                   ref_mask: jnp.ndarray | None = None,
                   compute_dtype: str = "float32",
                   interpret: bool = False) -> jnp.ndarray:
    """Row sums of ``d(X, Y)`` over the valid rows of Y for the MXU metrics,
    fused past the Gram stage.

    x: (C, d), y: (R, d) padded to block multiples; xn2: (C, 1), yn2: (1, R)
    squared row norms (ignored for cosine — pass zeros and pre-normalized
    x/y). By default the first ``r_true`` rows of Y are valid; ``ref_mask``
    (broadcastable to (R,), nonzero = valid) further restricts them — the
    ragged engine passes the per-draw arm-validity mask here. Returns (C, 1)
    f32 distance sums (not yet divided by the valid count).
    """
    if metric not in ("l2", "sql2", "cosine"):
        raise ValueError(f"dot_centrality does not support metric {metric!r}")
    c, d = x.shape
    r, _ = y.shape
    mask = (jnp.arange(r) < r_true).astype(jnp.float32)
    if ref_mask is not None:
        mask = mask * ref_mask.reshape(-1).astype(jnp.float32)
    grid = (c // BC, r // BR, d // BD)
    kern = functools.partial(_dot_centrality_kernel, metric=metric,
                             nk=d // BD,
                             compute_dtype=jnp.dtype(compute_dtype))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
            pl.BlockSpec((BC, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, BR), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, BR), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((BC, 1), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BC, BR), jnp.float32)],
        interpret=interpret,
    )(x, y, xn2, yn2, mask.reshape(1, r))


# --------------------------------------------------------------------------
# fused top-k survivor-selection epilogue: given the per-candidate centrality
# estimates a round's fused kernel just produced, pick the ``keep`` smallest
# arms ON-CHIP — the last remaining off-chip step of a round (XLA's generic
# sort over the (C,) estimates). Semantics replicate jax.lax.top_k(-theta, k)
# exactly, stable index tie-break included, so the survivor *order* (which
# seeds the next round's gather) is bit-identical to the default path.
#
# Two accumulation kernels in the house style (no sort network needed):
#
# * rank kernel, grid (i, j): rank[i] = #{j : theta[j] < theta[i]  or
#   (theta[j] == theta[i] and j < i)}. The strict total order makes `rank` a
#   permutation of [0, C), and the (BC, BC) comparison tile only ever lives
#   in VMEM/registers — the (C, C) comparison matrix is never materialized.
# * select kernel, grid (i,): out[s] = sum_i i * [rank[i] == s] — a one-hot
#   scatter of each index to its rank slot, accumulated over candidate tiles.
#
# Padded candidate rows carry +inf and indices above every real arm, so they
# rank strictly after all real arms (+inf ties break by index) and land in
# slots >= C that the wrapper slices off. Masked (+inf) *real* arms — the
# ragged engine's padded-arm estimates — get the same index-stable order
# top_k gives them.
# --------------------------------------------------------------------------

def _topk_rank_kernel(vc_ref, vr_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vi = vc_ref[...]                      # (BC, 1) this tile's arm estimates
    vj = vr_ref[...]                      # (1, BC) estimates being ranked against
    gi = i * BC + jax.lax.broadcasted_iota(jnp.int32, (BC, 1), 0)
    gj = j * BC + jax.lax.broadcasted_iota(jnp.int32, (1, BC), 1)
    beats = (vj < vi) | ((vj == vi) & (gj < gi))      # (BC, BC) broadcast
    o_ref[...] += jnp.sum(beats.astype(jnp.int32), axis=1, keepdims=True)


def _topk_select_kernel(r_ref, o_ref, *, kp: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rank = r_ref[...]                     # (BC, 1) int32, a permutation slice
    gi = i * BC + jax.lax.broadcasted_iota(jnp.int32, (BC, 1), 0)
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, kp), 1)
    hit = rank == slot                    # (BC, kp) one-hot over output slots
    o_ref[...] += jnp.sum(jnp.where(hit, gi, 0), axis=0, keepdims=True)


def topk_smallest(v: jnp.ndarray, kp: int, *,
                  interpret: bool = False) -> jnp.ndarray:
    """Indices of the ascending-sorted prefix of ``v``, on-chip.

    v: (Cp,) int32 *total-order keys* (see ``ops.kernel_topk_smallest`` —
    the float estimates are bitcast to the IEEE-totalorder monotone int so
    comparisons match XLA's sort exactly, -0.0 < +0.0 included), Cp a
    multiple of BC, padded with int32 max; kp: output slot count (multiple
    of 128, >= the ``keep`` the caller will slice, <= Cp). Returns (1, kp)
    int32 where slot s holds the index of the (s+1)-th smallest value,
    ties broken toward the smaller index — exactly
    ``jax.lax.top_k(-theta, kp)[1]`` restricted to the real arms.
    """
    cp = v.shape[0]
    grid_rank = (cp // BC, cp // BC)
    ranks = pl.pallas_call(
        _topk_rank_kernel,
        grid=grid_rank,
        in_specs=[
            pl.BlockSpec((BC, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, BC), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BC, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, 1), jnp.int32),
        interpret=interpret,
    )(v.reshape(cp, 1), v.reshape(1, cp))
    return pl.pallas_call(
        functools.partial(_topk_select_kernel, kp=kp),
        grid=(cp // BC,),
        in_specs=[pl.BlockSpec((BC, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, kp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, kp), jnp.int32),
        interpret=interpret,
    )(ranks)
