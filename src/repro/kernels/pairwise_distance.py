"""Pallas TPU kernels for the medoid engine's hot loop.

The paper's per-round hot spot is the rectangular distance block
``D[c, j] = d(X[S_r][c], X[J_r][j])`` plus its row-mean. On TPU we split the
metrics into two kernel families:

* **dot kernel** (MXU path): pairwise inner products ``G = X @ Y^T`` with f32
  accumulation. ℓ2 / squared-ℓ2 / cosine reduce to ``G`` plus O(nd) row norms
  computed outside the kernel (Gram trick), so the inner loop runs on the
  128x128 systolic array at full rate.

* **ℓ1 kernels** (VPU path): ``sum |x - y|`` has no matmul form. The kernel
  tiles ``(BC, BD) x (BR, BD)`` into VMEM and accumulates f32 partial sums,
  chunking the d-axis inside the block to bound the broadcast intermediate
  (BC x BR x CHUNK). Two variants:
    - ``l1_pairwise``  -> (C, R) distance matrix
    - ``l1_centrality``-> fused row-sum (C,): never materializes (C, R) in HBM,
      which is the memory-roofline win for large reference sets.

Grid layout: (i, j, k) with k (the d-axis) innermost so each output tile is
revisited across k steps and accumulated in place (standard Pallas reduction
pattern); the fused centrality kernel also folds j into the accumulation.

All wrappers in ``ops.py`` pad shapes to block multiples; padded d-columns are
zeros (contribute 0 to every metric), padded candidate rows are sliced off,
and padded reference rows are masked *inside* the kernels via the global
column index (closured static true size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Block sizes: MXU-aligned (multiples of 128 in the matmul dims). The ℓ1 VPU
# kernel keeps the same tile footprint but chunks d to bound VMEM.
BC = 128   # candidate rows per tile
BR = 128   # reference rows per tile
BD = 256   # d-axis slab per grid step
L1_CHUNK = 16  # d-chunk inside the ℓ1 kernel: BC*BR*CHUNK*4B = 1 MiB VMEM


# --------------------------------------------------------------------------
# dot kernel (MXU): G[c, r] = sum_d X[c, d] * Y[r, d]
# --------------------------------------------------------------------------

def _dot_kernel(x_ref, y_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    y = y_ref[...]
    o_ref[...] += jax.lax.dot_general(
        x, y, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def dot_pairwise(x: jnp.ndarray, y: jnp.ndarray, *,
                 interpret: bool = False) -> jnp.ndarray:
    """X: (C, d), Y: (R, d) — C, R, d already padded to block multiples."""
    c, d = x.shape
    r, _ = y.shape
    grid = (c // BC, r // BR, d // BD)
    return pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((BC, BR), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, r), jnp.float32),
        interpret=interpret,
    )(x, y)


# --------------------------------------------------------------------------
# ℓ1 pairwise kernel (VPU): D[c, r] = sum_d |X[c, d] - Y[r, d]|
# --------------------------------------------------------------------------

def _l1_pairwise_kernel(x_ref, y_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)   # (BC, BD)
    y = y_ref[...].astype(jnp.float32)   # (BR, BD)
    acc = jnp.zeros_like(o_ref)
    for c0 in range(0, BD, L1_CHUNK):    # static unroll: bound VMEM intermediate
        xs = x[:, c0:c0 + L1_CHUNK]
        ys = y[:, c0:c0 + L1_CHUNK]
        acc += jnp.sum(jnp.abs(xs[:, None, :] - ys[None, :, :]), axis=-1)
    o_ref[...] += acc


def l1_pairwise(x: jnp.ndarray, y: jnp.ndarray, *,
                interpret: bool = False) -> jnp.ndarray:
    c, d = x.shape
    r, _ = y.shape
    grid = (c // BC, r // BR, d // BD)
    return pl.pallas_call(
        _l1_pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((BC, BR), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, r), jnp.float32),
        interpret=interpret,
    )(x, y)


# --------------------------------------------------------------------------
# fused ℓ1 centrality kernel: S[c] = sum_{r < r_true} sum_d |X[c,d] - Y[r,d]|
# Never materializes the (C, R) matrix in HBM.
# --------------------------------------------------------------------------

def _l1_centrality_kernel(x_ref, y_ref, o_ref, *, r_true: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)   # (BC, BD)
    y = y_ref[...].astype(jnp.float32)   # (BR, BD)
    # mask padded reference rows by global row index
    col = j * BR + jax.lax.broadcasted_iota(jnp.int32, (BR, 1), 0)
    mask = (col < r_true).astype(jnp.float32)          # (BR, 1)
    acc = jnp.zeros_like(o_ref)                        # (BC, 1)
    for c0 in range(0, BD, L1_CHUNK):
        xs = x[:, c0:c0 + L1_CHUNK]
        ys = y[:, c0:c0 + L1_CHUNK]
        a = jnp.abs(xs[:, None, :] - ys[None, :, :])   # (BC, BR, CHUNK)
        # padded reference rows must not count: mask the whole (r) slice
        a = a * mask[None, :, :]
        acc += jnp.sum(a, axis=(1, 2), keepdims=False)[:, None]
    o_ref[...] += acc


def l1_centrality(x: jnp.ndarray, y: jnp.ndarray, r_true: int, *,
                  interpret: bool = False) -> jnp.ndarray:
    """Row sums of |X - Y| distances over the first ``r_true`` rows of Y.

    x: (C, d), y: (R, d) padded; returns (C, 1) f32 sums (not yet divided).
    """
    c, d = x.shape
    r, _ = y.shape
    grid = (c // BC, r // BR, d // BD)
    kern = functools.partial(_l1_centrality_kernel, r_true=r_true)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((BC, 1), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 1), jnp.float32),
        interpret=interpret,
    )(x, y)


# --------------------------------------------------------------------------
# fused dot-centrality kernel (MXU): S[c] = sum_{r < r_true} d(X[c], Y[r])
# for the Gram-trick metrics. The (BC, BR) distance tile lives only in a VMEM
# scratch accumulator — the (C, R) block is never materialized in HBM, which
# makes every metric's round memory-roofline-optimal, not just ℓ1.
#
# The d-axis (grid dim k, innermost) accumulates raw inner products into the
# scratch tile; at the last k step the metric's elementwise transform
# (sql2 / l2 / cosine) is applied to the *complete* Gram tile — sqrt does not
# commute with the d-reduction, hence the scratch carry — padded reference
# rows are masked by global row index, and the row-sum folds into o_ref.
# --------------------------------------------------------------------------

def _dot_centrality_kernel(x_ref, y_ref, xn_ref, yn_ref, o_ref, acc_ref, *,
                           metric: str, r_true: int, nk: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((j == 0) & (k == 0))
    def _init_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        g = acc_ref[...]                                   # (BC, BR) complete
        if metric == "cosine":
            # inputs pre-normalized outside: distance is 1 - <x̂, ŷ>
            v = 1.0 - g
        else:
            sq = jnp.maximum(xn_ref[...] + yn_ref[...] - 2.0 * g, 0.0)
            v = jnp.sqrt(sq) if metric == "l2" else sq
        col = j * BR + jax.lax.broadcasted_iota(jnp.int32, (1, BR), 1)
        v = v * (col < r_true).astype(jnp.float32)         # mask padded refs
        o_ref[...] += jnp.sum(v, axis=1, keepdims=True)    # (BC, 1)


def dot_centrality(x: jnp.ndarray, y: jnp.ndarray, xn2: jnp.ndarray,
                   yn2: jnp.ndarray, r_true: int, *, metric: str,
                   interpret: bool = False) -> jnp.ndarray:
    """Row sums of ``d(X, Y)`` over the first ``r_true`` rows of Y for the
    MXU metrics, fused past the Gram stage.

    x: (C, d), y: (R, d) padded to block multiples; xn2: (C, 1), yn2: (1, R)
    squared row norms (ignored for cosine — pass zeros and pre-normalized
    x/y). Returns (C, 1) f32 distance sums (not yet divided by r_true).
    """
    if metric not in ("l2", "sql2", "cosine"):
        raise ValueError(f"dot_centrality does not support metric {metric!r}")
    c, d = x.shape
    r, _ = y.shape
    grid = (c // BC, r // BR, d // BD)
    kern = functools.partial(_dot_centrality_kernel, metric=metric,
                             r_true=r_true, nk=d // BD)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BC, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BD), lambda i, j, k: (j, k)),
            pl.BlockSpec((BC, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, BR), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((BC, 1), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BC, BR), jnp.float32)],
        interpret=interpret,
    )(x, y, xn2, yn2)
