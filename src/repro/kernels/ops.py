"""Jit'd public wrappers around the Pallas kernels.

These handle padding to block multiples, metric plumbing (Gram trick for
ℓ2/sqℓ2/cosine), and CPU fallback: on non-TPU backends the kernels run in
``interpret=True`` mode (numerically identical, Python-executed) so the whole
framework is testable on this container. ``pairwise_kernel(metric)`` returns a
drop-in replacement for ``repro.core.distances.pairwise(metric)`` and can be
passed to ``correlated_sequential_halving(pairwise_fn=...)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import pairwise_distance as pk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(a: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_dot(x: jnp.ndarray, y: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """Pairwise inner products via the MXU kernel. (C, d) x (R, d) -> (C, R)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    c, r = x.shape[0], y.shape[0]
    xp = _pad_to(x, pk.BC, pk.BD)
    yp = _pad_to(y, pk.BR, pk.BD)
    return pk.dot_pairwise(xp, yp, interpret=interp)[:c, :r]


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_l1(x: jnp.ndarray, y: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    """Pairwise ℓ1 distances via the VPU kernel."""
    interp = (not _on_tpu()) if interpret is None else interpret
    c, r = x.shape[0], y.shape[0]
    xp = _pad_to(x, pk.BC, pk.BD)
    yp = _pad_to(y, pk.BR, pk.BD)
    return pk.l1_pairwise(xp, yp, interpret=interp)[:c, :r]


def _pad_ref_mask(ref_mask: jnp.ndarray | None, r: int,
                  r_pad: int) -> jnp.ndarray | None:
    """Pad a (r,) validity mask with zeros out to the kernel-padded length."""
    if ref_mask is None:
        return None
    m = ref_mask.reshape(-1).astype(jnp.float32)
    if r_pad > r:
        m = jnp.pad(m, (0, r_pad - r))
    return m


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_l1_centrality(x: jnp.ndarray, y: jnp.ndarray,
                         interpret: bool | None = None,
                         ref_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fused mean_j ℓ1(x_i, y_j): (C, d) x (R, d) -> (C,). Never materializes
    the (C, R) matrix — the memory-roofline optimization for big ref sets.
    With ``ref_mask`` (shape (R,), nonzero = valid) the mean runs over the
    valid references only."""
    interp = (not _on_tpu()) if interpret is None else interpret
    c, r = x.shape[0], y.shape[0]
    xp = _pad_to(x, pk.BC, pk.BD)
    yp = _pad_to(y, pk.BR, pk.BD)
    mask = _pad_ref_mask(ref_mask, r, yp.shape[0])
    sums = pk.l1_centrality(xp, yp, r_true=r, ref_mask=mask,
                            interpret=interp)[:c, 0]
    denom = r if ref_mask is None else jnp.maximum(jnp.sum(mask), 1.0)
    return sums / denom


def _norms_sq(a: jnp.ndarray) -> jnp.ndarray:
    af = a.astype(jnp.float32)
    return jnp.sum(af * af, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_sql2(x: jnp.ndarray, y: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    # NB: ``interpret`` is in kernel_dot's static_argnames — always forward it
    # by keyword so the static/traced split never depends on positional
    # signature resolution.
    g = kernel_dot(x, y, interpret=interpret)
    return jnp.maximum(_norms_sq(x)[:, None] + _norms_sq(y)[None, :] - 2.0 * g, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_l2(x: jnp.ndarray, y: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    return jnp.sqrt(kernel_sql2(x, y, interpret=interpret))


def _unit_rows(a: jnp.ndarray) -> jnp.ndarray:
    af = a.astype(jnp.float32)
    return af / jnp.maximum(jnp.linalg.norm(af, axis=-1, keepdims=True), 1e-12)


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_cosine(x: jnp.ndarray, y: jnp.ndarray, interpret: bool | None = None) -> jnp.ndarray:
    return 1.0 - kernel_dot(_unit_rows(x), _unit_rows(y), interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("metric", "interpret", "compute_dtype"))
def kernel_centrality_sums(x: jnp.ndarray, y: jnp.ndarray, *,
                           metric: str = "l2",
                           interpret: bool | None = None,
                           ref_mask: jnp.ndarray | None = None,
                           compute_dtype: str = "float32") -> jnp.ndarray:
    """Fused ``sum_j d(x_i, y_j)``: (C, d) x (R, d) -> (C,) distance sums.

    Every metric routes through a fused kernel (ℓ1 VPU kernel or the MXU
    ``dot_centrality`` kernel), so the (C, R) block never exists in HBM —
    the memory-roofline win, now for all four metrics. ``ref_mask`` (shape
    (R,), nonzero = valid) drops invalid references from the sum *inside*
    the kernel — the ragged engine's padded arms never contribute.

    ``compute_dtype="bfloat16"`` lowers the Gram-stage multiply precision
    inside the MXU kernel (norms, metric epilogue, and accumulation stay
    f32) — the quantized ``quant_bf16_fused`` backend's path. The ℓ1 VPU
    kernel has no matmul stage; its inputs are representation-rounded
    instead (the caller's job — see ``repro.quant.backends``).
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    c, r = x.shape[0], y.shape[0]
    if metric == "l1":
        xp = _pad_to(x, pk.BC, pk.BD)
        yp = _pad_to(y, pk.BR, pk.BD)
        mask = _pad_ref_mask(ref_mask, r, yp.shape[0])
        return pk.l1_centrality(xp, yp, r_true=r, ref_mask=mask,
                                interpret=interp)[:c, 0]
    if metric == "cosine":
        xf, yf = _unit_rows(x), _unit_rows(y)
        xn2 = jnp.zeros((c, 1), jnp.float32)   # unused by the cosine path
        yn2 = jnp.zeros((1, r), jnp.float32)
    elif metric in ("l2", "sql2"):
        xf = x.astype(jnp.float32)
        yf = y.astype(jnp.float32)
        xn2 = _norms_sq(xf)[:, None]
        yn2 = _norms_sq(yf)[None, :]
    else:
        raise ValueError(f"unknown metric {metric!r}")
    xp = _pad_to(xf, pk.BC, pk.BD)
    yp = _pad_to(yf, pk.BR, pk.BD)
    xn2p = _pad_to(xn2, pk.BC, 1)
    yn2p = _pad_to(yn2, 1, pk.BR)
    mask = _pad_ref_mask(ref_mask, r, yp.shape[0])
    return pk.dot_centrality(xp, yp, xn2p, yn2p, r, metric=metric,
                             ref_mask=mask, compute_dtype=compute_dtype,
                             interpret=interp)[:c, 0]


@functools.partial(jax.jit, static_argnames=("keep", "interpret"))
def kernel_topk_smallest(theta: jnp.ndarray, *, keep: int,
                         interpret: bool | None = None) -> jnp.ndarray:
    """Fused survivor-selection epilogue: indices of the ``keep`` smallest
    entries of ``theta (C,)``, ordered ascending with ties broken toward the
    smaller index — drop-in for ``jax.lax.top_k(-theta, keep)[1]`` (the round
    loop's halving step), computed by the on-chip rank/select kernel pair so
    survivor selection never leaves the chip."""
    interp = (not _on_tpu()) if interpret is None else interpret
    c = theta.shape[0]
    if not 0 < keep <= c:
        raise ValueError(f"keep must be in [1, {c}], got {keep}")
    cp = c + (-c) % pk.BC
    # IEEE-totalorder monotone int key (sign-flip bitcast): plain int
    # comparison then orders floats exactly like XLA's sort, including
    # -0.0 < +0.0 — plain float </== would merge the two zeros and diverge
    # from top_k on which one survives first.
    b = jax.lax.bitcast_convert_type(theta.astype(jnp.float32), jnp.int32)
    key = jnp.where(b >= 0, b, (~b) ^ jnp.int32(-(2 ** 31)))
    # int32-max-pad: padded rows rank strictly after every real arm (even
    # +inf estimates), so no real slot below ``c`` can point at padding.
    # kp <= cp always (keep <= c).
    v = jnp.pad(key, (0, cp - c), constant_values=jnp.iinfo(jnp.int32).max)
    kp = min(cp, keep + (-keep) % 128)
    return pk.topk_smallest(v, kp, interpret=interp)[0, :keep]


_KERNELS = {
    "l1": kernel_l1,
    "l2": kernel_l2,
    "sql2": kernel_sql2,
    "cosine": kernel_cosine,
}


def pairwise_kernel(metric: str):
    """Kernel-backed drop-in for ``repro.core.distances.pairwise(metric)``."""
    try:
        return _KERNELS[metric]
    except KeyError:
        raise ValueError(f"unknown metric {metric!r}") from None


def centrality_kernel(metric: str):
    """Fused row-sum centrality for ``metric``: ``f(x, y) -> (C,)`` sums."""
    if metric not in _KERNELS:
        raise ValueError(f"unknown metric {metric!r}")
    return functools.partial(kernel_centrality_sums, metric=metric)
