"""granite-moe-3b-a800m — MoE, 40 experts top-8, d_expert=512
[hf:ibm-granite/granite-3.0; hf]."""
from repro.configs.base import ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    rope_theta=10_000.0, tie_embeddings=True,
    moe=MoECfg(num_experts=40, top_k=8, d_expert=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=64, vocab_size=256, head_dim=16,
                      moe=MoECfg(num_experts=8, top_k=2, d_expert=64))
