"""deepseek-v2-lite-16b — MLA (kv_lora=512) + MoE 64 routed top-6 + 2 shared
experts [arXiv:2405.04434; hf]. Decode uses the absorbed MLA formulation with
the compressed (512+64)-per-token cache."""
from repro.configs.base import MLACfg, ModelCfg, MoECfg

CONFIG = ModelCfg(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    rope_theta=10_000.0,
    moe=MoECfg(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
    mla=MLACfg(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
               nope_head_dim=128, v_head_dim=128),
    source="arXiv:2405.04434; hf",
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                      d_ff=96, vocab_size=256,
                      moe=MoECfg(num_experts=8, top_k=2, num_shared=1, d_expert=96),
                      mla=MLACfg(kv_lora_rank=32, rope_head_dim=8,
                                 nope_head_dim=16, v_head_dim=16))
