"""llama-3.2-vision-11b — GQA decoder with gated cross-attention image layers
every 5th layer [hf:meta-llama/Llama-3.2-11B-Vision; unverified]. The vision
tower is a STUB per the assignment: input_specs feeds precomputed patch
embeddings (B, num_image_tokens, d_model)."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0, cross_attn_every=5, num_image_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)

SMOKE = CONFIG.scaled(num_layers=10, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=256, head_dim=16,
                      cross_attn_every=5, num_image_tokens=16)
