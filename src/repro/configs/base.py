"""Config schema for every supported architecture + the input-shape suite."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    num_shared: int = 0          # always-on shared experts (DeepSeek style)
    d_expert: int = 0            # expert FFN hidden size (0 -> d_ff)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512      # compressed KV dim (cached at decode)
    q_lora_rank: int = 0         # 0 -> no query compression (v2-lite)
    rope_head_dim: int = 64      # decoupled RoPE dims appended to the cache
    nope_head_dim: int = 128     # per-head non-rope dims
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    d_conv: int = 4
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256             # SSD chunk length (training parallel form)


@dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    act: str = "silu"            # silu (gated) | gelu (non-gated enc-dec)
    gated_mlp: bool = True
    tie_embeddings: bool = False
    # sliding-window pattern: per-layer window sizes, tiled over layers.
    # 0 = global attention. e.g. gemma3: (1024,)*5 + (0,)  (5 local : 1 global)
    window_pattern: Tuple[int, ...] = ()
    # per-layer rope theta override matching window_pattern tiling (gemma3 uses
    # 1M for global layers); 0 entries fall back to rope_theta.
    rope_theta_pattern: Tuple[float, ...] = ()
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    # ssm/hybrid/xlstm block pattern, tiled: entries in {"attn","mamba2","mlstm","slstm"}
    block_pattern: Tuple[str, ...] = ()
    # hybrid (zamba2): a single *shared* attention block applied after every
    # `shared_attn_every` ssm blocks (0 = none)
    shared_attn_every: int = 0
    # vlm: insert a cross-attention layer every k self-attn layers (0 = none)
    cross_attn_every: int = 0
    num_image_tokens: int = 1600
    # audio/enc-dec: encoder depth (decoder depth = num_layers)
    encoder_layers: int = 0
    num_audio_frames: int = 1500
    dtype: str = "bfloat16"
    # notes for DESIGN/roofline bookkeeping
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def layer_windows(self, n: Optional[int] = None) -> Tuple[int, ...]:
        n = n or self.num_layers
        if not self.window_pattern:
            return (0,) * n
        p = self.window_pattern
        return tuple(p[i % len(p)] for i in range(n))

    def layer_thetas(self, n: Optional[int] = None) -> Tuple[float, ...]:
        n = n or self.num_layers
        if not self.rope_theta_pattern:
            return (self.rope_theta,) * n
        p = self.rope_theta_pattern
        return tuple((p[i % len(p)] or self.rope_theta) for i in range(n))

    def scaled(self, **kw) -> "ModelCfg":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# archs that may run the 500k-decode cell (sub-quadratic / windowed / recurrent)
LONG_CONTEXT_OK = {"xlstm-1.3b", "zamba2-2.7b", "gemma3-27b"}


def cell_is_supported(cfg: ModelCfg, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if not."""
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: 500k context skipped per spec"
    return True, ""
