"""xlstm-1.3b — sLSTM + mLSTM blocks, 7:1 ratio [arXiv:2405.04517; unverified].
48 layers = 6 super-blocks of (7 mLSTM + 1 sLSTM)."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
)

SMOKE = CONFIG.scaled(num_layers=8, d_model=64, num_heads=4, num_kv_heads=4,
                      vocab_size=256, block_pattern=("mlstm",) * 3 + ("slstm",))
