"""zamba2-2.7b — Mamba2 backbone + ONE shared full-attention block applied
after every 6 Mamba blocks (Zamba weight-sharing) [arXiv:2411.15242; hf].
ssm_state=64 per the assignment spec."""
from repro.configs.base import ModelCfg, SSMCfg

CONFIG = ModelCfg(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm=SSMCfg(d_state=64, d_conv=4, head_dim=64, expand=2, chunk=256),
    shared_attn_every=6, tie_embeddings=True,
    source="arXiv:2411.15242; hf",
)

SMOKE = CONFIG.scaled(num_layers=6, d_model=64, num_heads=4, num_kv_heads=4,
                      d_ff=128, vocab_size=256, head_dim=16,
                      ssm=SSMCfg(d_state=16, head_dim=16, chunk=16),
                      shared_attn_every=3)
