"""qwen2.5-14b — dense GQA with QKV bias [hf:Qwen/Qwen2.5; hf]."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=13824, vocab_size=152064, head_dim=128,
    rope_theta=1_000_000.0, qkv_bias=True,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=256, head_dim=16)
