"""command-r-35b — dense GQA, no biases [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000, head_dim=128,
    rope_theta=8_000_000.0, qkv_bias=False, tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
                      d_ff=160, vocab_size=256, head_dim=8)
