from repro.configs.base import (InputShape, MLACfg, ModelCfg, MoECfg, SHAPES,
                                SSMCfg, cell_is_supported)
from repro.configs.registry import (ARCH_NAMES, all_cells, get_config,
                                    get_smoke_config, input_specs, list_configs)
