"""whisper-small — encoder-decoder audio backbone [arXiv:2212.04356;
unverified]. Conv frontend stubbed: input_specs feeds (B, 1500, 768) frame
embeddings per the assignment."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    encoder_layers=12, num_audio_frames=1500,
    act="gelu", gated_mlp=False, tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                      d_ff=128, vocab_size=256, head_dim=16,
                      encoder_layers=2, num_audio_frames=24)
