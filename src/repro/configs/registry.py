"""Architecture registry + ShapeDtypeStruct input specs for every cell."""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, InputShape, ModelCfg, cell_is_supported

_MODULES = {
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "command-r-35b": "repro.configs.command_r_35b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "whisper-small": "repro.configs.whisper_small",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelCfg:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke_config(name: str) -> ModelCfg:
    return importlib.import_module(_MODULES[name]).SMOKE


def list_configs() -> Dict[str, ModelCfg]:
    return {n: get_config(n) for n in ARCH_NAMES}


# ------------------------------------------------------------ input specs --

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelCfg, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: the full token batch (+ modality stubs).
    decode: one new token per sequence (the KV cache spec comes from
    ``cache_specs``)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "audio":
            batch["frames"] = _sds((B, cfg.num_audio_frames, cfg.d_model), dt)
        if cfg.family == "vlm":
            batch["image_embed"] = _sds((B, cfg.num_image_tokens, cfg.d_model), dt)
        return batch
    # decode: one token per sequence
    return {"token": _sds((B,), jnp.int32)}


def cache_specs(cfg: ModelCfg, shape: InputShape) -> dict:
    """Shape/dtype of the decode cache at context length = shape.seq_len."""
    from repro.models.model import build_model
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(shape.global_batch,
                                                   shape.seq_len))


def all_cells():
    """Yield (arch_name, shape, supported, reason) for all 40 cells."""
    for name in ARCH_NAMES:
        cfg = get_config(name)
        for shape in SHAPES.values():
            ok, reason = cell_is_supported(cfg, shape)
            yield name, shape, ok, reason
