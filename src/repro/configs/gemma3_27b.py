"""gemma3-27b — dense GQA, 5:1 local(1024):global attention, 128k context
[hf:google/gemma-3-*-pt; unverified]. Local layers use rope theta 10k,
global layers 1M (the pattern rides through the layer scan as data)."""
from repro.configs.base import ModelCfg

CONFIG = ModelCfg(
    name="gemma3-27b", family="dense",
    num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16,
    d_ff=21504, vocab_size=262144, head_dim=128,
    rope_theta=10_000.0, tie_embeddings=True,
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
    rope_theta_pattern=(10_000.0,) * 5 + (1_000_000.0,),
    source="hf:google/gemma-3-1b-pt; unverified",
)

# 62 = 10 * 6 + 2: the pattern tiling handles the remainder layers.
SMOKE = CONFIG.scaled(num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=256, head_dim=16,
                      window_pattern=(8, 8, 8, 8, 8, 0))
