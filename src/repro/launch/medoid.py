"""Medoid engine driver — the paper's algorithm as a service.

Runs Correlated Sequential Halving (single-device or distributed over
whatever mesh exists), with per-round survivor checkpointing so a preempted
job restarts mid-algorithm (rounds are idempotent given (seed, round)).

``--backend`` selects the distance implementation from the registry in
``repro.core.backend`` (reference | pallas_pairwise | pallas_fused |
pallas_fused_topk); ``--batch B`` answers B independent queries in one
dispatch via ``repro.api.find_medoids_batch``. All modes are thin wrappers
over the :mod:`repro.api` facade.

Observability (:mod:`repro.obs`): ``--trace PATH`` runs the query with
device-resident round telemetry (bit-identical answers, same single
dispatch) and streams span / round / select events to JSONL;
``--metrics-out PATH`` writes the engine odometers as a Prometheus text
exposition; ``--profile-dir DIR`` brackets the run in
``jax.profiler.start_trace``/``stop_trace`` with the bandit phases
annotated onto the profiler timeline.

Example:
  PYTHONPATH=src python -m repro.launch.medoid --n 4096 --d 512 \
      --metric l1 --budget-per-arm 30 --dataset rnaseq20k_like \
      --backend pallas_fused --batch 8 --trace /tmp/medoid_trace.jsonl
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.api import find_medoid, find_medoids_batch
from repro.checkpoint import manager as ckpt
from repro.core import (exact_medoid, list_backends, rand_medoid,
                        round_schedule, schedule_pulls)
from repro.core.distributed import make_row_sharding
from repro.data.medoid_datasets import DATASETS, planted_medoid
from repro.runtime.fault_tolerance import elastic_remesh


def run(n: int, d: int, metric: str, budget_per_arm: int, dataset: str,
        *, seed: int = 0, use_kernel: bool = False, distributed: bool = False,
        compare: bool = False, ckpt_dir: str | None = None,
        backend: str = "reference", batch: int = 0, trace=None,
        precision: str = "fp32") -> dict:
    key = jax.random.key(seed)
    if use_kernel and backend == "reference":
        backend = "pallas_pairwise"   # legacy flag -> kernel-backed blocks

    def gen_data(k):
        if dataset in DATASETS:
            return DATASETS[dataset][1](k, n, d)
        return planted_medoid(k, n, d)

    if dataset in DATASETS:
        metric = metric or DATASETS[dataset][0]
    else:
        metric = metric or "l2"
    if batch > 0 and distributed:
        raise ValueError("--batch and --distributed are mutually exclusive; "
                         "the batched engine is single-host (vmap)")
    data = None if batch > 0 else gen_data(key)

    budget = budget_per_arm * n
    sched = round_schedule(n, budget)
    out = {"n": n, "d": d, "metric": metric, "budget": budget,
           "backend": backend, "precision": precision,
           "pulls_scheduled": schedule_pulls(n, budget),
           "rounds": [(r.survivors, r.num_refs) for r in sched]}

    cfg_kw = dict(metric=metric, backend=backend,
                  budget_per_arm=budget_per_arm, precision=precision)
    if distributed and precision != "fp32":
        raise ValueError("--precision requires the single-host engine; "
                         "run without --distributed")
    # --trace: switch the facade to the telemetry-carrying program variant
    # (answers stay bit-identical; the distributed engine isn't instrumented)
    with_tel = trace is not None and not (distributed
                                          and len(jax.devices()) > 1)
    dispatch_span = (trace.span("dispatch", mode=out.get("mode", backend))
                     if trace is not None else contextlib.nullcontext())
    t0 = time.time()
    with dispatch_span:
        if batch > 0:
            # multi-query mode: B independent candidate sets, one dispatch
            batch_data = jnp.stack([gen_data(jax.random.fold_in(key, 100 + b))
                                    for b in range(batch)])
            res = find_medoids_batch(batch_data, jax.random.fold_in(key, 1),
                                     telemetry=with_tel, **cfg_kw)
            medoids, tel = res if with_tel else (res, None)
            out["mode"] = f"batch x{batch} ({backend})"
            out["medoids"] = [int(m) for m in medoids]
            medoid = out["medoids"][0]
            data = batch_data[0]
            if trace is not None and tel is not None:
                for slot, m in enumerate(out["medoids"]):
                    trace.record_rounds(tel, slot=slot, slot_id=slot)
                    trace.event("select", winner=m,
                                pulls=int(tel["pulls"][slot].sum()), n=n,
                                algo="corr_sh", metric=metric,
                                backend=backend, slot_id=slot)
        elif distributed and len(jax.devices()) > 1:
            mesh = elastic_remesh(preferred_tp=1)
            data_sh = jax.device_put(data, make_row_sharding(mesh))
            medoid = find_medoid(data_sh, jax.random.fold_in(key, 1),
                                 mesh=mesh, distributed_impl="v2",
                                 **cfg_kw).medoid
            out["mode"] = f"distributed-v2 x{len(jax.devices())} ({backend})"
        else:
            res = find_medoid(data, jax.random.fold_in(key, 1),
                              telemetry=with_tel, **cfg_kw)
            medoid = res.medoid
            out["mode"] = backend
            if precision != "fp32":
                # True: the quantized certificate held; False: the answer
                # came from the exact fp32 fallback (exact either way)
                out["verified"] = res.verified
            if trace is not None:
                trace.record_result(res)
    out["medoid"] = medoid
    out["corrsh_s"] = round(time.time() - t0, 3)
    if with_tel and batch == 0:
        out["telemetry"] = {k: v.tolist()
                            for k, v in (res.telemetry or {}).items()}

    if ckpt_dir:
        ckpt.save(ckpt_dir, 0, {"medoid": jnp.asarray(medoid)},
                  extra={"n": n, "metric": metric, "budget": budget})

    if compare:
        t0 = time.time()
        truth = int(exact_medoid(data, metric))
        out["exact"] = truth
        out["exact_s"] = round(time.time() - t0, 3)
        out["correct"] = truth == medoid
        t0 = time.time()
        out["rand"] = int(rand_medoid(data, jax.random.fold_in(key, 2),
                                      num_refs=min(n, 1000), metric=metric))
        out["rand_s"] = round(time.time() - t0, 3)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=512)
    ap.add_argument("--metric", default="", choices=["", "l1", "l2", "sql2", "cosine"])
    ap.add_argument("--budget-per-arm", type=int, default=30)
    ap.add_argument("--dataset", default="planted",
                    choices=["planted"] + list(DATASETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="legacy alias for --backend pallas_pairwise")
    ap.add_argument("--backend", default="reference",
                    choices=list(list_backends()))
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="distance precision: quantized Gram backends with "
                         "margin-widened halving and exact fp32 survivor "
                         "verification (answers stay fp32-exact)")
    ap.add_argument("--batch", type=int, default=0,
                    help="answer B independent queries in one dispatch")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compile cache directory (repeat "
                         "runs skip recompiling known program signatures)")
    ap.add_argument("--trace", default=None, metavar="PATH", dest="trace_out",
                    help="stream span/round/select events to this JSONL "
                         "file (runs with device-resident telemetry; "
                         "answers stay bit-identical)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the engine trace/dispatch odometers as a "
                         "Prometheus text exposition on exit")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="bracket the run in jax.profiler.start_trace/"
                         "stop_trace writing here (bandit phases annotated)")
    args = ap.parse_args(argv)
    if args.compile_cache:
        from repro.engine.programs import enable_persistent_cache
        enable_persistent_cache(args.compile_cache)
    session = None
    if args.trace_out or args.profile_dir:
        from repro.obs import TraceSession
        session = TraceSession(args.trace_out,
                               annotate=args.profile_dir is not None,
                               profiler_dir=args.profile_dir,
                               meta={"workload": "medoid",
                                     "backend": args.backend, "n": args.n,
                                     "d": args.d, "seed": args.seed})
    try:
        print(json.dumps(run(args.n, args.d, args.metric,
                             args.budget_per_arm,
                             args.dataset, seed=args.seed,
                             use_kernel=args.use_kernel,
                             distributed=args.distributed,
                             compare=args.compare,
                             ckpt_dir=args.ckpt_dir, backend=args.backend,
                             batch=args.batch, trace=session,
                             precision=args.precision)))
    finally:
        if session is not None:
            session.close()
        if args.metrics_out:
            from repro.obs import instrument_exposition
            with open(args.metrics_out, "w") as fh:
                fh.write(instrument_exposition())


if __name__ == "__main__":
    main()
