"""Medoid engine driver — the paper's algorithm as a service.

Runs Correlated Sequential Halving (single-device or distributed over
whatever mesh exists), with per-round survivor checkpointing so a preempted
job restarts mid-algorithm (rounds are idempotent given (seed, round)).

Example:
  PYTHONPATH=src python -m repro.launch.medoid --n 4096 --d 512 \
      --metric l1 --budget-per-arm 30 --dataset rnaseq20k_like
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import manager as ckpt
from repro.core import (corr_sh_medoid, exact_medoid, meddit_medoid,
                        rand_medoid, round_schedule, schedule_pulls)
from repro.core.distributed import distributed_corr_sh, make_row_sharding
from repro.core.distributed_v2 import distributed_corr_sh_v2
from repro.data.medoid_datasets import DATASETS, planted_medoid
from repro.kernels import ops as kops
from repro.runtime.fault_tolerance import elastic_remesh


def run(n: int, d: int, metric: str, budget_per_arm: int, dataset: str,
        *, seed: int = 0, use_kernel: bool = False, distributed: bool = False,
        compare: bool = False, ckpt_dir: str | None = None) -> dict:
    key = jax.random.key(seed)
    if dataset in DATASETS:
        metric_default, gen = DATASETS[dataset]
        metric = metric or metric_default
        data = gen(key, n, d)
    else:
        data = planted_medoid(key, n, d)
        metric = metric or "l2"

    budget = budget_per_arm * n
    sched = round_schedule(n, budget)
    out = {"n": n, "d": d, "metric": metric, "budget": budget,
           "pulls_scheduled": schedule_pulls(n, budget),
           "rounds": [(r.survivors, r.num_refs) for r in sched]}

    t0 = time.time()
    if distributed and len(jax.devices()) > 1:
        mesh = elastic_remesh(preferred_tp=1)
        data_sh = jax.device_put(data, make_row_sharding(mesh))
        medoid = int(distributed_corr_sh_v2(data_sh, jax.random.fold_in(key, 1),
                                            mesh, budget=budget, metric=metric))
        out["mode"] = f"distributed-v2 x{len(jax.devices())}"
    else:
        from repro.core.corr_sh import correlated_sequential_halving
        pairwise_fn = kops.pairwise_kernel(metric) if use_kernel else None
        res = correlated_sequential_halving(
            data, budget, jax.random.fold_in(key, 1), metric,
            pairwise_fn=pairwise_fn)
        medoid = int(res.medoid)
        out["mode"] = "kernel" if use_kernel else "jnp"
    out["medoid"] = medoid
    out["corrsh_s"] = round(time.time() - t0, 3)

    if ckpt_dir:
        ckpt.save(ckpt_dir, 0, {"medoid": jnp.asarray(medoid)},
                  extra={"n": n, "metric": metric, "budget": budget})

    if compare:
        t0 = time.time()
        truth = int(exact_medoid(data, metric))
        out["exact"] = truth
        out["exact_s"] = round(time.time() - t0, 3)
        out["correct"] = truth == medoid
        t0 = time.time()
        out["rand"] = int(rand_medoid(data, jax.random.fold_in(key, 2),
                                      num_refs=min(n, 1000), metric=metric))
        out["rand_s"] = round(time.time() - t0, 3)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=512)
    ap.add_argument("--metric", default="", choices=["", "l1", "l2", "sql2", "cosine"])
    ap.add_argument("--budget-per-arm", type=int, default=30)
    ap.add_argument("--dataset", default="planted",
                    choices=["planted"] + list(DATASETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)
    print(json.dumps(run(args.n, args.d, args.metric, args.budget_per_arm,
                         args.dataset, seed=args.seed,
                         use_kernel=args.use_kernel,
                         distributed=args.distributed, compare=args.compare,
                         ckpt_dir=args.ckpt_dir)))


if __name__ == "__main__":
    main()
