"""Parameter / batch / cache PartitionSpec assignment.

Rule-based: each rule maps a parameter path regex to a spec for the TRAILING
dims of the leaf; leading dims (layer-scan stacks, group stacks) are padded
with None automatically, so the same rules cover scanned and unscanned params.

Tensor-parallel layout (Megatron-style):
  column-parallel:  wq/wk/wv/w_up/w_gate/w_in/w_uk/w_uv/lm_head  (out dim on model)
  row-parallel:     wo/w_down/w_out                              (in  dim on model)
  embeddings:       vocab dim on model
  MoE experts:      TP *inside* each expert (hidden dim on model) — works for
                    any expert count; EP (expert dim on model) is selected
                    instead when num_experts divides the model axis (the
                    dispatch einsum then shards on the expert axis).
  norms/scalars:    replicated
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelCfg
from repro.launch.mesh import batch_axes

# (path regex, spec for trailing dims)
_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)embed$", ("model", None)),
    (r"(^|/)pos_dec$", (None, None)),
    (r"(^|/)lm_head$", (None, "model")),
    (r"(^|/)img_proj$", (None, "model")),
    (r"(^|/)router$", (None, None)),
    (r"(^|/)(wq|wk|wv|w_up|w_gate|w_in|w_q|w_k|w_v|w_uk|w_uv)$", (None, "model")),
    (r"(^|/)(wo|w_down|w_out)$", ("model", None)),
    (r"(^|/)(w_dkv|w_krope)$", (None, None)),
    (r"(^|/)(bq|bk|bv)$", ("model",)),
    (r"(^|/)conv_w$", (None, "model")),
    (r"(^|/)conv_b$", ("model",)),
    (r"(^|/)(w_i|w_f|R|A_log|D|dt_bias|b|gate)$", None),  # small: replicate
]

_MOE_EP_RULES = [
    # expert-parallel: expert dim on model axis
    (r"ffn.*(w_gate|w_up|w_down)$", ("model", None, None)),
]


def _spec_for(path: str, ndim: int, moe_ep: bool) -> P:
    rules = (_MOE_EP_RULES + _RULES) if moe_ep else _RULES
    for pat, spec in rules:
        if re.search(pat, path):
            if spec is None:
                return P()
            pad = (None,) * (ndim - len(spec))
            return P(*(pad + tuple(spec)))
    return P()  # default: replicate (norm scales etc.)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def moe_uses_ep(cfg: ModelCfg, mesh: Mesh) -> bool:
    if cfg.moe is None:
        return False
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    return cfg.moe.num_experts % model_size == 0


def param_specs(params_shape, cfg: ModelCfg, mesh: Mesh):
    """Pytree of PartitionSpec matching a params (shape-)pytree."""
    ep = moe_uses_ep(cfg, mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]

    specs = []
    for path, leaf in flat:
        spec = _spec_for(_path_str(path), leaf.ndim, ep)
        # divisibility guard: drop model-axis sharding where it doesn't divide
        clean = []
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            if ax == "model" and dim % model_size != 0:
                clean.append(None)
            else:
                clean.append(ax)
        specs.append(P(*clean))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_shape, cfg: ModelCfg, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, cfg, mesh))


def zero_specs(params_shape, pspecs, mesh: Mesh, axes=None):
    """ZeRO/FSDP extension of param specs: additionally shard the first
    still-unsharded, divisible dim of every large leaf over pod x data.
    Applied to optimizer moments always (ZeRO-2) and to params for very large
    models (FSDP); XLA SPMD inserts the reduce-scatter / all-gather pattern.
    """
    baxes = tuple(axes) if axes is not None else batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bsize = 1
    for a in baxes:
        bsize *= sizes[a]

    def one(leaf, spec):
        if leaf.size < (1 << 20):          # don't bother below 1M elements
            return spec
        cur = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
        for i, (d, ax) in enumerate(zip(leaf.shape, cur)):
            if ax is None and d % bsize == 0 and d >= bsize:
                new = list(cur)
                new[i] = baxes
                return P(*new)
        return spec

    return jax.tree.map(one, params_shape, pspecs)


def pure_fsdp_specs(params_shape, mesh: Mesh):
    """ZeRO-3 layout: every large leaf sharded over ALL mesh axes jointly on
    its first divisible dim; no tensor parallelism. XLA re-gathers one
    layer's params per scan iteration (cheap for very large d_model, where
    per-layer activation all-reduces under TP dwarf per-layer param bytes)."""
    axes = tuple(mesh.axis_names)
    total = 1
    for s in mesh.devices.shape:
        total *= s

    def one(leaf):
        if leaf.size < (1 << 20):
            return P()
        for i, d in enumerate(leaf.shape):
            if d % total == 0 and d >= total:
                spec = [None] * leaf.ndim
                spec[i] = axes
                return P(*spec)
        # fall back to partial sharding on the largest axis product that fits
        return P()

    return jax.tree.map(one, params_shape)


def batch_specs(batch_shape, mesh: Mesh, axes=None):
    """Shard the leading (batch) dim of every batch leaf over pod x data
    (or an explicit axis tuple, e.g. all axes for pure-FSDP cells)."""
    baxes = tuple(axes) if axes is not None else batch_axes(mesh)
    bsize = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in baxes:
        bsize *= sizes[a]

    def one(leaf):
        if leaf.shape and leaf.shape[0] % bsize == 0 and leaf.shape[0] > 1:
            return P(baxes, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(one, batch_shape)


def cache_specs_tree(cache_shape, cfg: ModelCfg, mesh: Mesh, batch: int,
                     seq_len: int = 0, shard_seq: bool = True):
    """Decode-cache sharding: batch dim over pod x data when it divides, and
    — the §Perf decode optimization — the SEQUENCE dim over the model axis.

    Sequence-sharding the cache turns decode attention into partial-softmax
    work per shard: the QK einsum emits seq-sharded scores with no
    communication, softmax reductions psum scalars, and PV contracts the
    sharded seq dim into a tiny (B, H, Dh) psum. The baseline alternative
    (head-dim sharded cache) made XLA all-gather the whole per-layer cache
    every step (measured 26 GB/chip/step on internlm2 decode_32k).
    Falls back to head/head-dim sharding when no dim matches seq_len.

    Cache layouts carry 1-2 leading stack dims (layers / groups) then batch;
    the batch dim is detected as the first dim equal to `batch`.
    """
    baxes = batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bsize = 1
    for a in baxes:
        bsize *= sizes[a]
    msize = sizes["model"]

    def one(leaf):
        spec = [None] * leaf.ndim
        # batch axis
        bdim = None
        for i, d in enumerate(leaf.shape):
            if d == batch and i <= 2:
                bdim = i
                break
        if bdim is not None and batch % bsize == 0 and batch > 1:
            spec[bdim] = baxes
        # sequence axis over model (preferred for decode; see docstring)
        if shard_seq and seq_len:
            for i in range((bdim + 1) if bdim is not None else 1, leaf.ndim):
                if leaf.shape[i] == seq_len and seq_len % msize == 0:
                    spec[i] = "model"
                    return P(*spec)
        # fallback: model axis on the trailing head/feature dims
        start = (bdim or 0)
        for i in range(leaf.ndim - 1, max(leaf.ndim - 3, start), -1):
            d = leaf.shape[i]
            if d % msize == 0 and d >= msize:
                spec[i] = "model"
                break
        return P(*spec)

    return jax.tree.map(one, cache_shape)
