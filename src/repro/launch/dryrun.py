import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun``) — the
XLA_FLAGS line above executes before any jax import so the host platform
exposes 512 placeholder devices for the production meshes. Smoke tests and
benchmarks never import this module.

Per cell this proves, without hardware:
  * the pjit shardings are coherent (lower succeeds),
  * SPMD partitioning succeeds for 16x16 and 2x16x16 (compile succeeds),
  * the per-chip memory footprint fits (memory_analysis),
and extracts the §Roofline inputs (cost_analysis + collective bytes from the
post-optimization HLO).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, cell_is_supported, get_config, input_specs  # noqa: E402
from repro.configs.registry import ARCH_NAMES  # noqa: E402
from repro.launch import partition  # noqa: E402
from repro.launch.mesh import logical_rules, make_production_mesh  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.models.sharding import logical_axis_rules  # noqa: E402
from repro.roofline import analysis as RA  # noqa: E402
from repro.train.train_step import TrainCfg, init_train_state, make_train_step  # noqa: E402


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                tcfg=None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = build_model(cfg)
    rules = logical_rules(mesh)  # refined below for train cells

    t0 = time.time()
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = partition.param_specs(params_shape, cfg, mesh)
    n_params = RA.count_params(params_shape)

    # memory policy: microbatch count + FSDP kick in by model size
    n_batch_shards = chips // 16   # pod x data
    per_dev_batch = max(1, shape.global_batch // n_batch_shards)
    if cfg.d_model >= 4096:
        target = 2
    elif cfg.d_model >= 2048:
        target = 4
    else:
        target = 8
    mb = max(1, per_dev_batch // target)
    while shape.global_batch % mb:
        mb -= 1
    if cfg.moe is not None and cfg.moe.num_experts % 16:
        # XLA SPMD verifier bug: microbatch reshape x TP-in-expert sharding
        # with non-divisible expert counts trips a dynamic-slice check
        # (granite, 40 experts on a 16-way model axis). mb=1 compiles clean.
        mb = 1
    param_bytes_per_chip = 2 * n_params / 16     # bf16, model-axis sharded
    fsdp = param_bytes_per_chip > 3e9
    # §Perf: very large d_model trains as pure FSDP/ZeRO-3 — batch over ALL
    # mesh axes, no tensor parallelism. Per-layer param gathers (~2 GB) are
    # far cheaper than per-layer activation all-reduces under TP=16
    # (measured 3.3 TB/chip/step on command-r train_4k). Falls back to batch
    # over pod x data with sequence-sharded activations when the global
    # batch doesn't divide the chip count.
    # measured (EXPERIMENTS §Perf): per-layer param gathers are 1-2 orders
    # cheaper than per-layer TP activation all-reduces at these batch sizes
    # — all train cells go pure-FSDP, EXCEPT MoE archs whose expert count
    # divides the model axis (deepseek 64e): expert-parallel dispatch beats
    # re-gathering the full expert stack (measured 18.5s EP vs 23.4s FSDP).
    fsdp_pure = shape.kind == "train" and not (
        cfg.moe is not None and cfg.moe.num_experts % 16 == 0)
    # NOTE: a plain-DP (replicated params) mode was hypothesized for small
    # models and MEASURED WORSE (whisper 3.8s vs 0.11s under FSDP: per-chip
    # batch grows 16x when the model axis idles, inflating activation
    # collectives and memory). Refuted; FSDP stays the train default.
    pure_dp = False
    seq_shard = False
    batch_over = None
    if fsdp_pure:
        mb = 1
        if shape.global_batch % chips == 0:
            batch_over = tuple(mesh.axis_names)
        else:
            seq_shard = shape.seq_len % 16 == 0
    if tcfg is None:
        tcfg = TrainCfg(remat=True, num_microbatches=mb)

    batch_sds = input_specs(cfg, shape)

    if shape.kind == "train":
        rules = logical_rules(mesh, seq_shard=seq_shard)
        if fsdp_pure:
            rules["model"] = None      # no tensor parallelism
            rules["expert"] = None
            if batch_over is not None:
                rules["batch"] = batch_over
                rules["vocab"] = None  # model axis taken by batch; fused CE
                                       # keeps chunk logits small anyway
            # params + moments fully sharded over all axes (ZeRO-3)
            pspecs = partition.pure_fsdp_specs(params_shape, mesh)
            zspecs = pspecs
        elif fsdp:
            pspecs = partition.zero_specs(params_shape, pspecs, mesh)
            zspecs = partition.zero_specs(params_shape, pspecs, mesh)
        else:
            zspecs = partition.zero_specs(params_shape, pspecs, mesh)
        state_shape = jax.eval_shape(
            lambda: init_train_state(model, jax.random.key(0), tcfg))
        state_specs = type(state_shape)(
            params=pspecs,
            opt=type(state_shape.opt)(step=P(), mu=zspecs, nu=zspecs),
            ef=None if state_shape.ef is None else type(state_shape.ef)(
                error=zspecs),
            step=P(),
        )
        bspecs = partition.batch_specs(batch_sds, mesh, axes=batch_over)
        step_fn = make_train_step(model, tcfg)

        def wrapped(state, batch):
            with logical_axis_rules(rules):
                return step_fn(state, batch)

        jitted = jax.jit(
            wrapped,
            in_shardings=(_ns(mesh, state_specs), _ns(mesh, bspecs)),
            out_shardings=(_ns(mesh, state_specs), None),
            donate_argnums=(0,),
        )
        with mesh:
            lowered = jitted.lower(state_shape, batch_sds)
        model_flops = RA.model_flops_train(
            n_params, shape.global_batch * shape.seq_len,
            active_frac=_active_frac(cfg))
    elif shape.kind == "prefill":
        bspecs = partition.batch_specs(batch_sds, mesh)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cspecs = partition.cache_specs_tree(cache_shape, cfg, mesh,
                                            shape.global_batch,
                                            seq_len=shape.seq_len)

        def wrapped(params, batch):
            with logical_axis_rules(rules):
                return model.prefill(params, batch, shape.seq_len)

        jitted = jax.jit(
            wrapped,
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)),
            out_shardings=(None, _ns(mesh, cspecs)),
        )
        with mesh:
            lowered = jitted.lower(params_shape, batch_sds)
        model_flops = RA.model_flops_train(
            n_params, shape.global_batch * shape.seq_len,
            active_frac=_active_frac(cfg)) / 3.0   # fwd only
    else:  # decode
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cspecs = partition.cache_specs_tree(cache_shape, cfg, mesh,
                                            shape.global_batch,
                                            seq_len=shape.seq_len)
        token_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

        def wrapped(params, token, cache, pos):
            with logical_axis_rules(rules):
                return model.decode_step(params, token, cache, pos)

        jitted = jax.jit(
            wrapped,
            in_shardings=(_ns(mesh, pspecs), None, _ns(mesh, cspecs), None),
            out_shardings=(None, _ns(mesh, cspecs)),
            donate_argnums=(2,),
        )
        with mesh:
            lowered = jitted.lower(params_shape, token_sds, cache_shape, pos_sds)
        model_flops = RA.model_flops_decode(
            n_params, shape.global_batch, active_frac=_active_frac(cfg))

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # dynamic-bound attention loops (inference paths) have unparseable trip
    # counts; hint = average causal coverage of the kv-block loop
    hint = max(1.0, shape.seq_len / 1024 / 2) if shape.kind == "prefill" else 1.0
    roof = RA.from_compiled(compiled, chips=chips, model_flops=model_flops,
                            hlo_text=hlo, while_hint=hint)
    coll = RA.parse_collectives(hlo)

    result = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "params": n_params, "microbatches": tcfg.num_microbatches,
        "fsdp": bool(fsdp), "fsdp_pure": bool(fsdp_pure),
        "pure_dp": bool(pure_dp),
        "seq_shard": bool(seq_shard),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device_bytes": {
            "arguments": int(mem.argument_size_in_bytes),
            "output": int(mem.output_size_in_bytes),
            "temp": int(mem.temp_size_in_bytes),
            "alias": int(mem.alias_size_in_bytes),
            "total_live": int(mem.argument_size_in_bytes
                              + mem.output_size_in_bytes
                              + mem.temp_size_in_bytes
                              - mem.alias_size_in_bytes),
        },
        "collectives": {"bytes": coll.bytes_by_kind,
                        "count": coll.count_by_kind},
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in roof.row().items()},
    }
    if verbose:
        print(json.dumps(result))
        sys.stdout.flush()
    return result


def _active_frac(cfg) -> float:
    """Active-parameter fraction for MoE archs (for 6*N_active*D)."""
    if cfg.moe is None:
        return 1.0
    m = cfg.moe
    d_e = m.d_expert or cfg.d_ff
    # per-layer moe params vs activated subset (+ shared always on)
    routed = m.num_experts * 3 * cfg.d_model * d_e
    active = (m.top_k + m.num_shared) * 3 * cfg.d_model * d_e
    dense_rest_guess = 4 * cfg.d_model * cfg.d_model
    per_layer = routed + dense_rest_guess
    per_layer_active = active + dense_rest_guess
    return per_layer_active / per_layer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_NAMES))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every supported (arch x shape) cell")
    ap.add_argument("--out", default=None, help="write JSONL results here")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        try:
            r = dryrun_cell(arch, shape, multi_pod=args.multi_pod)
        except Exception as e:  # noqa: BLE001 — report & continue
            r = {"arch": arch, "shape": shape, "status": "error",
                 "mesh": "2x16x16" if args.multi_pod else "16x16",
                 "error": f"{type(e).__name__}: {e}",
                 "trace": traceback.format_exc()[-2000:]}
            print(json.dumps({k: r[k] for k in
                              ("arch", "shape", "status", "error")}))
            sys.stdout.flush()
        results.append(r)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(r) + "\n")

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"# dry-run done: {n_ok} ok, {n_skip} skipped, {n_err} errors",
          file=sys.stderr)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    sys.exit(main())


def dryrun_medoid_engine(*, n: int = 1 << 20, d: int = 1024,
                         budget_per_arm: int = 24, metric: str = "l1",
                         multi_pod: bool = False, verbose: bool = True,
                         engine: str = "v2") -> dict:
    """Dry-run the paper's engine itself on the production mesh: lower +
    compile distributed corrSH over an (n, d) row-sharded dataset."""
    from repro.core.distributed import make_distributed_corr_sh, make_row_sharding
    from repro.core.distributed_v2 import make_distributed_corr_sh_v2
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    maker = make_distributed_corr_sh if engine == "v1" else make_distributed_corr_sh_v2
    fn = maker(mesh, n=n, d=d, budget=budget_per_arm * n, metric=metric)
    x_sds = jax.ShapeDtypeStruct((n, d), jnp.float32)
    key_sds = jax.ShapeDtypeStruct((), jnp.uint32)
    import time as _t
    t0 = _t.time()
    with mesh:
        lowered = jax.jit(fn.__wrapped__ if hasattr(fn, "__wrapped__") else fn,
                          in_shardings=(make_row_sharding(mesh), None),
                          ).lower(x_sds, jax.eval_shape(
                              lambda: jax.random.key(0)))
        compiled = lowered.compile()
    t_compile = _t.time() - t0
    hlo = compiled.as_text()
    from repro.core.corr_sh import schedule_pulls
    # model flops: distance evals x (3d for l1) across all chips
    per_pull = {"l1": 3 * d, "l2": 2 * d, "sql2": 2 * d, "cosine": 2 * d}[metric]
    model_flops = float(schedule_pulls(n, budget_per_arm * n)) * per_pull
    roof = RA.from_compiled(compiled, chips=chips, model_flops=model_flops,
                            hlo_text=hlo)
    mem = compiled.memory_analysis()
    result = {
        "arch": f"corrsh-engine-{engine}", "shape": f"n{n}_d{d}_b{budget_per_arm}",
        "status": "ok", "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "compile_s": round(t_compile, 1),
        "per_device_bytes": {
            "arguments": int(mem.argument_size_in_bytes),
            "temp": int(mem.temp_size_in_bytes),
            "total_live": int(mem.argument_size_in_bytes
                              + mem.output_size_in_bytes
                              + mem.temp_size_in_bytes
                              - mem.alias_size_in_bytes)},
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in roof.row().items()},
    }
    if verbose:
        print(json.dumps(result))
    return result
