"""Serving driver: batched prefill + decode loop with continuous batching.

Production structure on a real pod; runs end-to-end on CPU with reduced
configs. Requests enter a queue; the scheduler packs them into the fixed
decode batch, prefills new sequences, decodes one token per step for every
live sequence, and retires finished ones (continuous batching — slot reuse).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b --smoke \
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.configs.registry import ARCH_NAMES
from repro.models.model import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-batch continuous-batching decode server (greedy sampling)."""

    def __init__(self, arch: str, *, smoke: bool = True, batch_slots: int = 4,
                 max_len: int = 256):
        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        self.model = build_model(self.cfg)
        self.max_len = max_len
        self.slots = batch_slots
        self.params = self.model.init(jax.random.key(0))
        self._decode = jax.jit(
            lambda p, tok, cache, pos: self.model.decode_step(p, tok, cache, pos))
        # one cache per slot (slot-wise so prefill can replace one sequence)
        self.caches = [None] * batch_slots
        self.positions = [0] * batch_slots
        self.live: list[Optional[Request]] = [None] * batch_slots

    def _extra(self, batch_size: int):
        extra = {}
        dt = jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32
        if self.cfg.family == "audio":
            extra["frames"] = jnp.zeros(
                (batch_size, self.cfg.num_audio_frames, self.cfg.d_model), dt)
        if self.cfg.family == "vlm":
            extra["image_embed"] = jnp.zeros(
                (batch_size, self.cfg.num_image_tokens, self.cfg.d_model), dt)
        return extra

    def admit(self, req: Request) -> bool:
        for i in range(self.slots):
            if self.live[i] is None:
                batch = {"tokens": req.prompt[None, :], **self._extra(1)}
                logits, cache = self.model.prefill(self.params, batch,
                                                   self.max_len)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                req.out.append(int(tok[0]))
                self.caches[i] = cache
                self.positions[i] = req.prompt.shape[0]
                self.live[i] = req
                return True
        return False

    def step(self):
        """One decode step for every live slot (slot-batched serially here;
        on hardware the slots share one batched decode_step)."""
        for i, req in enumerate(self.live):
            if req is None:
                continue
            tok = jnp.asarray([req.out[-1]], jnp.int32)
            logits, self.caches[i] = self._decode(
                self.params, tok, self.caches[i], self.positions[i])
            nxt = int(jnp.argmax(logits, -1)[0])
            req.out.append(nxt)
            self.positions[i] += 1
            if len(req.out) >= req.max_new or self.positions[i] >= self.max_len - 1:
                req.done = True
                self.live[i] = None

    def run(self, requests: list[Request]) -> dict:
        pending = list(requests)
        t0 = time.time()
        steps = 0
        while pending or any(r is not None for r in self.live):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            self.step()
            steps += 1
        return {"requests": len(requests), "decode_steps": steps,
                "wall_s": round(time.time() - t0, 2),
                "tokens": sum(len(r.out) for r in requests)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args(argv)

    srv = Server(args.arch, smoke=args.smoke)
    key = jax.random.key(7)
    reqs = [Request(rid=i,
                    prompt=jax.random.randint(jax.random.fold_in(key, i),
                                              (args.prompt_len,), 0,
                                              srv.cfg.vocab_size),
                    max_new=args.max_new)
            for i in range(args.requests)]
    print(json.dumps(srv.run(reqs)))


if __name__ == "__main__":
    main()
