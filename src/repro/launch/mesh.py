"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run flow where
XLA_FLAGS must be set before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips, one v5e pod) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def logical_rules(mesh, seq_shard: bool = False) -> dict:
    """Logical-axis mapping installed around model code (see models.sharding).

    ``seq_shard=True`` maps the logical "seq" axis (used on residual-stream
    constraints) to the model axis — Megatron-style sequence parallelism:
    activations between blocks live seq-sharded, attention/MLP gather/scatter
    around their TP compute, halving collective bytes vs all-reduce and
    cutting live activation memory by the TP degree. Enabled per-cell by the
    launcher for large-d_model training shapes.
    """
    return {
        "batch": batch_axes(mesh),
        "model": "model",
        "expert": "model",
        "vocab": "model",   # vocab/logits sharding survives pure-FSDP mode
        "seq": "model" if seq_shard else None,
    }
