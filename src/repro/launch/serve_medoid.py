"""Continuous-batching medoid service over the ragged multi-query engine.

The medoid analogue of :mod:`repro.launch.serve`'s admit/step loop: clients
submit independent medoid queries (a ``(n, d)`` candidate set each, arbitrary
``n`` per request); the scheduler coalesces queued requests into power-of-two
shape buckets (:mod:`repro.core.bucketing`), pads each group to a fixed slot
count, and answers a whole bucket in one ragged-engine dispatch (the same
path as :func:`repro.api.find_medoids_ragged`). Because every dispatch has
the same static signature per bucket — ``(max_batch, n_bucket, d)`` with a
bucket-derived budget — the engine compiles at most one XLA program per
distinct bucket no matter how traffic is shaped, and the compile odometer
(``ragged_compile_count``) lets tests and benchmarks assert exactly that.

Per-request accounting mirrors a serving stack: queue-wait steps, batch wall
time, and the schedule's pull count (distance evaluations) for the bucket the
request rode in. ``warmup()`` pre-traces expected buckets — BOTH program
variants, base and telemetry-carrying — before traffic arrives, and
``compile_cache_dir=`` (CLI ``--compile-cache``) points jax's persistent
compilation cache at a directory so a *restarted* server never re-compiles a
bucket it has ever seen.

Multi-tenant scheduling (``policy=`` / CLI ``--policy``): requests carry an
optional priority and absolute deadline; the ``"edf"`` policy serves the
earliest deadline first and sheds requests whose deadline became infeasible
(priced from the live compile-vs-steady latency histograms through
:class:`repro.serve.scheduler.LatencyModel`). The default ``"fifo"`` policy
reproduces the original arrival-order behavior exactly.

Observability (see :mod:`repro.obs`): every server carries a
:class:`~repro.obs.metrics.ServerMetrics` bundle — per-bucket
request/answer/pull counters plus queue-wait, batch-occupancy and
compile-vs-steady dispatch-latency histograms — exposed as a JSON
:meth:`MedoidServer.metrics` snapshot and a Prometheus text
:meth:`MedoidServer.exposition` (CLI ``--metrics-out``). Passing a
:class:`~repro.obs.trace.TraceSession` (CLI ``--trace``) additionally runs
every dispatch with device-resident round telemetry and streams span /
round / select events to JSONL — with per-round pull sums that reconcile
exactly with the reported totals (``python -m repro.obs.validate`` checks).

Example:
  PYTHONPATH=src python -m repro.launch.serve_medoid --requests 24 \
      --n-min 16 --n-max 700 --d 32 --backend pallas_fused \
      --trace /tmp/medoid_trace.jsonl --metrics-out /tmp/medoid_metrics.txt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import get_backend, list_backends, round_schedule
from repro.core.bucketing import DEFAULT_MIN_BUCKET, bucket_n, pack_queries
from repro.core.corr_sh import ragged_compile_count, ragged_medoids
from repro.core.distances import METRICS
from repro.engine import programs, stop_round
from repro.obs import ServerMetrics, TraceSession, instrument_exposition, \
    telemetry_to_host
from repro.serve.scheduler import LatencyModel, resolve_policy
from repro import quant


@dataclasses.dataclass
class MedoidRequest:
    """One queued medoid query and, once answered, its result + accounting.

    ``priority`` / ``deadline_s`` feed the scheduling policy (see
    :mod:`repro.serve.scheduler`): the deadline is *absolute* on the
    server's clock, priority breaks ties among equal deadlines under EDF.
    A request the scheduler gave up on (its deadline became infeasible)
    lands in ``server.shed`` with ``shed=True`` and no medoid."""
    rid: int
    data: jnp.ndarray                  # (n, d) candidate set
    submit_step: int
    priority: int = 0                  # higher = more urgent (EDF tie-break)
    deadline_s: Optional[float] = None  # absolute, on the server's clock
    medoid: Optional[int] = None       # index < n once answered
    wait_steps: int = 0                # scheduler steps spent queued
    batch_wall_s: float = 0.0          # wall time of the dispatch it rode in
    pulls: int = 0                     # scheduled distance evals of that dispatch
    submit_s: float = 0.0              # server-clock admission time
    finish_s: Optional[float] = None   # server-clock answer/shed time
    shed: bool = False                 # dropped unanswered by the policy
    deadline_met: Optional[bool] = None  # answered in time? (None: no deadline)
    gap: Optional[float] = None        # final-round winner gap (hardness)

    @property
    def n(self) -> int:
        return int(self.data.shape[0])

    @property
    def done(self) -> bool:
        return self.medoid is not None


class MedoidServer:
    """Continuous-batching medoid server (admit / step / drain).

    One ``step()`` asks the scheduling policy (``policy=`` — ``"fifo"``
    default, ``"edf"`` for earliest-deadline-first with load shedding, see
    :mod:`repro.serve.scheduler`) which bucket group to service: the chosen
    requests share one ``(n_bucket, d)`` signature, up to ``max_batch`` of
    them, dispatched as one ragged batch padded to exactly ``max_batch``
    slots (dummy length-1 queries fill the tail, so group size never
    changes the compiled signature). Remaining requests wait for the next
    step; under FIFO this is exactly the original oldest-bucket-group
    behavior, bit for bit.
    """

    def __init__(self, *, metric: str = "l2", backend: str = "reference",
                 budget_per_arm: int = 24, max_batch: int = 8,
                 min_bucket: int = DEFAULT_MIN_BUCKET, seed: int = 0,
                 compile_cache_dir: Optional[str] = None,
                 trace: Optional[TraceSession] = None,
                 policy="fifo", clock=None, collect_gaps: bool = True,
                 latency_quantile: float = 0.9, precision: str = "fp32",
                 quant_error_model: str = "probe"):
        if metric not in METRICS:
            raise ValueError(f"unknown metric {metric!r}; one of {METRICS}")
        get_backend(backend)      # fail at construction, not mid-dispatch
        quant.check_precision(precision)
        if quant_error_model not in quant.ERROR_MODELS:
            raise ValueError(f"unknown error model {quant_error_model!r}; "
                             f"one of {quant.ERROR_MODELS}")
        if compile_cache_dir:
            # persistent XLA cache: a restarted server re-traces known
            # buckets (cheap) but never re-compiles them (expensive)
            programs.enable_persistent_cache(compile_cache_dir)
        self.metric = metric
        self.backend = backend
        # precision != "fp32" runs every dispatch on the quantized Gram
        # backend with margin-widened halving + exact fp32 verification
        # (see repro.quant); a batch whose certificate fails is re-answered
        # by ONE exact fp32 dispatch with the same key, so served answers
        # are always fp32-exact. ``quant_fallbacks`` counts those re-runs.
        self.precision = precision
        self.quant_error_model = quant_error_model
        self.quant_fallbacks = 0
        self.budget_per_arm = budget_per_arm
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.queue: list[MedoidRequest] = []
        self.done: dict[int, MedoidRequest] = {}
        self.shed: dict[int, MedoidRequest] = {}
        self.dispatches = 0
        self.buckets_seen: set[tuple[int, int]] = set()   # (n_bucket, d)
        self._step = 0
        self._next_rid = 0
        self._key = jax.random.key(seed)
        self._recompiles = 0
        # observability: metrics are always on (host-side counters cost
        # nothing on the device path); a TraceSession additionally switches
        # every dispatch to the telemetry-carrying program variant (same
        # single dispatch, bit-identical answers) and streams span / round /
        # select events to JSONL. ``collect_gaps`` rides the same telemetry
        # variant WITHOUT a trace session to feed the winner-gap hardness
        # histogram (answers stay bit-identical either way).
        self.trace = trace
        self.collect_gaps = collect_gaps
        self._metrics = ServerMetrics()
        # scheduling: policy objects are pure queue transformers (see
        # repro.serve.scheduler); the latency model prices a request's
        # bucket from the live compile-vs-steady dispatch histograms, and
        # the clock (monotonic unless injected — tests inject a fake) is
        # the timeline deadlines are expressed on.
        self._policy = resolve_policy(policy)
        self._clock = clock if clock is not None else time.monotonic
        self._latency_model = LatencyModel(self._metrics,
                                           quantile=latency_quantile)

    @property
    def policy(self) -> str:
        return getattr(self._policy, "name", type(self._policy).__name__)

    @property
    def _telemetry_on(self) -> bool:
        return self.trace is not None or self.collect_gaps

    # ------------------------------- admission ----------------------------
    def submit(self, data: jnp.ndarray, rid: Optional[int] = None, *,
               priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Queue one (n, d) query; returns its request id. Rejects empty or
        mis-shaped queries at admission (never mid-dispatch).

        ``priority`` and ``deadline_s`` (absolute, on the server's clock —
        ``now() + budget`` for a relative budget) feed the scheduling
        policy; under the default FIFO policy they are recorded but do not
        reorder anything."""
        data = jnp.asarray(data)
        if data.ndim != 2:
            raise ValueError(f"query must be (n, d), got shape {data.shape}")
        if data.shape[0] < 1:
            raise ValueError("all-padding query rejected: n must be >= 1")
        if rid is None:
            rid = self._next_rid
        if rid in self.done or rid in self.shed \
                or any(q.rid == rid for q in self.queue):
            raise ValueError(f"duplicate request id {rid}")
        self._next_rid = max(self._next_rid, rid) + 1
        self.queue.append(MedoidRequest(rid=rid, data=data,
                                        submit_step=self._step,
                                        priority=priority,
                                        deadline_s=deadline_s,
                                        submit_s=self._clock()))
        self._metrics.record_submit(
            self._bucket_label(*self._bucket_key(self.queue[-1])))
        return rid

    def now(self) -> float:
        """The server's clock (deadlines are absolute on this timeline)."""
        return self._clock()

    @property
    def pending(self) -> int:
        return len(self.queue)

    # -------------------------------- warmup ------------------------------
    def warmup(self, shapes: list[tuple[int, int]]) -> dict:
        """Pre-trace the dispatch program for each ``(n, d)`` signature by
        answering a dummy batch at that bucket — a warmed server's first real
        ``step()`` on a known bucket retraces nothing (and with a persistent
        compile cache, a *restarted* warmed server recompiles nothing: warmup
        pays tracing, XLA lowering is read back from disk). Warmup programs
        don't count against :attr:`recompiles` — that odometer only tracks
        traces observed during live dispatches. Returns per-bucket wall
        times and the trace count the warmup itself incurred."""
        timings: dict = {"buckets": {}, "traces": 0, "wall_s": 0.0}
        compiles0 = ragged_compile_count()
        t_all = time.time()
        # warm EVERY program variant a live dispatch can select, at its
        # exact dispatch-time cache key. The variant depends on runtime
        # state (trace attached? gap collection toggled? quantized
        # certificate failed?), and each is its own cached program —
        # warming only one would leave the first metered call on another
        # variant compiling:
        #   * base and telemetry-carrying, at the server's precision
        #     (quantized dispatches keep the buffer for a possible
        #     fallback, so they run donate=False — fp32 donates);
        #   * for a quantized server, additionally the exact fp32
        #     fallback program (donate=True, no telemetry) that answers a
        #     batch whose verification certificate failed.
        variants = [(self.precision, with_tel, self.precision == "fp32")
                    for with_tel in (False, True)]
        if self.precision != "fp32":
            variants.append(("fp32", False, True))
        for n, d in shapes:
            n_bucket = bucket_n(max(1, int(n)), self.min_bucket)
            t0 = time.time()
            for prec, with_tel, don in variants:
                data, lengths = pack_queries(
                    [jnp.zeros((1, int(d)), jnp.float32)],
                    min_bucket=n_bucket, pad_batch_to=self.max_batch)
                jax.block_until_ready(ragged_medoids(
                    data, lengths, jax.random.key(0),
                    budget=self.budget_per_arm * n_bucket,
                    metric=self.metric, backend=self.backend,
                    min_bucket=self.min_bucket, donate=don,
                    telemetry=with_tel, precision=prec,
                    error_model=self.quant_error_model))
            timings["buckets"][f"{n_bucket}x{int(d)}"] = round(
                time.time() - t0, 4)
        timings["traces"] = ragged_compile_count() - compiles0
        timings["wall_s"] = round(time.time() - t_all, 4)
        return timings

    # ------------------------------ scheduling ----------------------------
    def _bucket_key(self, req: MedoidRequest) -> tuple[int, int]:
        return (bucket_n(req.n, self.min_bucket), int(req.data.shape[1]))

    @staticmethod
    def _bucket_label(n_bucket: int, d: int) -> str:
        return f"{n_bucket}x{d}"

    def _estimate(self, req: MedoidRequest) -> Optional[float]:
        """Seconds one dispatch of ``req``'s bucket should take (None: the
        latency model has no applicable observation yet)."""
        bkey = self._bucket_key(req)
        return self._latency_model.estimate(self._bucket_label(*bkey),
                                            compiled=bkey in self.buckets_seen)

    def step(self) -> list[MedoidRequest]:
        """Service the scheduling policy's chosen bucket group; returns the
        answered requests. Requests the policy shed (deadline infeasible)
        land in :attr:`shed` with ``shed=True``."""
        self._step += 1
        if not self.queue:
            return []
        now = self._clock()
        batch, rest, shed = self._policy.select(
            self.queue, now=now, max_batch=self.max_batch,
            bucket_key=self._bucket_key, estimate=self._estimate)
        for q in shed:
            q.shed = True
            q.finish_s = self._clock()
            q.wait_steps = self._step - q.submit_step - 1
            self.shed[q.rid] = q
            label = self._bucket_label(*self._bucket_key(q))
            self._metrics.record_shed(label)
            self._metrics.record_deadline(label, False)
            if self.trace is not None:
                self.trace.event("shed", rid=q.rid, bucket=label, n=q.n,
                                 deadline_s=q.deadline_s, step=self._step)
        self.queue = rest
        if not batch:
            return []
        bkey = self._bucket_key(batch[0])
        n_bucket, _ = bkey

        # (max_batch, n_bucket, d) with dummy length-1 tail slots: group size
        # never changes the compiled signature
        data, lengths = pack_queries([q.data for q in batch],
                                     min_bucket=self.min_bucket,
                                     pad_batch_to=self.max_batch)
        budget = self.budget_per_arm * n_bucket
        self._key, sub = jax.random.split(self._key)

        label = self._bucket_label(*bkey)
        with_tel = self._telemetry_on
        compiles0 = ragged_compile_count()
        t0 = time.time()
        fellback = False
        try:
            # donate only on the fp32 path: the packed batch buffer is
            # server-owned and dead after an fp32 dispatch, but a quantized
            # dispatch may need it again for the exact fp32 fallback — the
            # fallback dispatch (the buffer's last use) takes it instead
            out = ragged_medoids(
                data, lengths, sub, budget=budget, metric=self.metric,
                backend=self.backend, min_bucket=self.min_bucket,
                donate=self.precision == "fp32", telemetry=with_tel,
                precision=self.precision,
                error_model=self.quant_error_model)
            if self.precision == "fp32":
                medoids, tel = out if with_tel else (out, None)
            else:
                if with_tel:
                    medoids, verified, tel = out
                else:
                    (medoids, verified), tel = out, None
                if not bool(jnp.all(verified)):
                    # certificate failed for some slot: ONE exact fp32
                    # re-dispatch with the same key answers the whole
                    # batch; verified slots keep the (identical) quantized
                    # answer. Served answers are always fp32-exact.
                    fellback = True
                    fout = ragged_medoids(
                        data, lengths, sub, budget=budget,
                        metric=self.metric, backend=self.backend,
                        min_bucket=self.min_bucket, donate=True,
                        telemetry=False)
                    medoids = jnp.where(verified, medoids, fout)
            medoids = [int(m) for m in medoids]      # block until ready
        except Exception:
            # dispatch failed: requests go back to the head of the queue so
            # nothing is ever lost between `queue` and `done`
            self.queue = batch + self.queue
            raise
        wall = time.time() - t0
        traced = ragged_compile_count() - compiles0
        self._recompiles += traced

        # executed-round accounting (matches the facade and the telemetry
        # rows; identical to schedule_pulls whenever the schedule ends at
        # its output round, which round_schedule guarantees)
        rounds = round_schedule(n_bucket, budget)
        stop = stop_round(rounds)
        pulls = sum(r.pulls for r in rounds[: stop + 1])
        if self.precision != "fp32":
            # the exact verification epilogue's distance evals, plus the
            # full fp32 re-run when the certificate failed
            pulls += quant.verify_pulls(n_bucket, rounds)
            if fellback:
                self.quant_fallbacks += 1
                pulls += sum(r.pulls for r in rounds[: stop + 1])
        self.dispatches += 1
        self.buckets_seen.add(bkey)
        finish = self._clock()
        for slot, q in enumerate(batch):
            q.medoid = medoids[slot]
            q.wait_steps = self._step - q.submit_step - 1
            q.batch_wall_s = round(wall, 4)
            q.pulls = pulls
            q.finish_s = finish
            if q.deadline_s is not None:
                q.deadline_met = finish <= q.deadline_s
                self._metrics.record_deadline(label, q.deadline_met)
            self.done[q.rid] = q
        self._metrics.record_dispatch(
            label, wall_s=wall, batch=len(batch), slots=self.max_batch,
            pulls_per_request=pulls, waits=[q.wait_steps for q in batch],
            compiled=traced > 0)
        tel_host = telemetry_to_host(tel) if with_tel else None
        if tel_host is not None and len(rounds):
            # final executed round's winner gap per slot: the server's
            # per-query hardness signal (NaN — fewer than two alive arms —
            # is dropped by the histogram)
            for slot, q in enumerate(batch):
                q.gap = float(tel_host["gap"][slot, stop])
                self._metrics.record_gap(label, q.gap)
        if self.trace is not None:
            self.trace.event("span", name="dispatch", dur_s=round(wall, 6),
                             traces={"ragged": traced} if traced else {},
                             dispatches={"ragged": 1}, bucket=label,
                             batch=len(batch), step=self._step)
            if fellback:
                self.trace.event("quant_fallback", bucket=label,
                                 precision=self.precision, step=self._step)
            for slot, q in enumerate(batch):
                # per-request rows: batched queries share the schedule
                # columns but each slot's alive/theta/gap are its own
                self.trace.record_rounds(tel_host, slot=slot, rid=q.rid,
                                         bucket=label)
                self.trace.event("select", winner=q.medoid, pulls=q.pulls,
                                 n=q.n, rid=q.rid, bucket=label,
                                 wait_steps=q.wait_steps)
        return batch

    def drain(self) -> dict[int, MedoidRequest]:
        """Step until the queue is empty; returns all answered requests."""
        while self.queue:
            self.step()
        return self.done

    # ------------------------------- telemetry ----------------------------
    @property
    def recompiles(self) -> int:
        """XLA programs the ragged engine traced during THIS server's
        dispatches (<= len(buckets_seen) by construction of the fixed
        dispatch shape; a cache warmed by another server only lowers it)."""
        return self._recompiles

    def stats(self) -> dict:
        lat = [q.wait_steps for q in self.done.values()]
        deadlined = [q for q in self.done.values()
                     if q.deadline_met is not None]
        return {
            "answered": len(self.done),
            "pending": len(self.queue),
            "shed": len(self.shed),
            "dispatches": self.dispatches,
            "distinct_buckets": len(self.buckets_seen),
            "recompiles": self.recompiles,
            "mean_wait_steps": round(sum(lat) / len(lat), 2) if lat else 0.0,
            "max_wait_steps": max(lat) if lat else 0,
            "total_pulls": sum(q.pulls for q in self.done.values()),
            "deadlines_met": sum(q.deadline_met for q in deadlined),
            "deadlines_missed": sum(not q.deadline_met for q in deadlined),
            "policy": self.policy,
            "backend": self.backend,
            "metric": self.metric,
            "precision": self.precision,
            "quant_fallbacks": self.quant_fallbacks,
        }

    def metrics(self) -> dict:
        """JSON-able snapshot of the per-bucket serving metrics (counters:
        value per label set; histograms: bucket counts + sum + count)."""
        return self._metrics.snapshot()

    def exposition(self) -> str:
        """Prometheus text exposition of the serving metrics, with the
        engine-wide trace/dispatch odometers appended — one artifact shows
        both per-bucket serving behavior and compile-vs-steady traffic."""
        return self._metrics.exposition() + instrument_exposition()


def synthetic_trace(num: int, n_lo: int, n_hi: int, d: int,
                    seed: int = 0) -> list[jnp.ndarray]:
    """A mixed-size query stream: log-uniform n in [n_lo, n_hi]."""
    key = jax.random.key(seed)
    out = []
    for i in range(num):
        u = float(jax.random.uniform(jax.random.fold_in(key, 2 * i)))
        n = max(n_lo, min(n_hi, round(math.exp(
            math.log(n_lo) + u * (math.log(n_hi) - math.log(n_lo))))))
        out.append(jax.random.normal(jax.random.fold_in(key, 2 * i + 1),
                                     (n, d)))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--n-min", type=int, default=16)
    ap.add_argument("--n-max", type=int, default=512)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--metric", default="l2",
                    choices=["l1", "l2", "sql2", "cosine"])
    ap.add_argument("--backend", default="reference",
                    choices=list(list_backends()))
    ap.add_argument("--precision", default="fp32",
                    choices=list(quant.PRECISIONS),
                    help="distance precision: quantized Gram + margin-"
                         "widened halving + exact fp32 verification "
                         "(failed certificates fall back to one exact "
                         "fp32 dispatch)")
    ap.add_argument("--budget-per-arm", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--arrivals-per-step", type=int, default=4,
                    help="requests admitted between scheduler steps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="fifo", choices=["fifo", "edf"],
                    help="scheduling policy: fifo (arrival order, default) "
                         "or edf (earliest-deadline-first with load "
                         "shedding)")
    ap.add_argument("--deadline-frac", type=float, default=0.0,
                    help="fraction of synthetic requests carrying a "
                         "deadline (0 disables deadlines)")
    ap.add_argument("--deadline-s", type=float, default=0.5,
                    help="relative deadline budget (seconds from admission) "
                         "for deadlined synthetic requests")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compile cache directory (restarted "
                         "servers skip recompiling known buckets)")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-trace every bucket the synthetic trace will "
                         "hit before admitting any request")
    ap.add_argument("--trace", default=None, metavar="PATH", dest="trace_out",
                    help="stream span/round/select events to this JSONL file "
                         "(dispatches run with device-resident telemetry; "
                         "answers stay bit-identical)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition of the "
                         "serving metrics here on exit")
    args = ap.parse_args(argv)
    if args.arrivals_per_step < 1:
        ap.error("--arrivals-per-step must be >= 1")

    session = TraceSession(args.trace_out, meta={
        "workload": "serve_medoid", "backend": args.backend,
        "metric": args.metric}) if args.trace_out else None
    srv = MedoidServer(metric=args.metric, backend=args.backend,
                       budget_per_arm=args.budget_per_arm,
                       max_batch=args.max_batch, seed=args.seed,
                       compile_cache_dir=args.compile_cache,
                       trace=session, policy=args.policy,
                       precision=args.precision)
    trace = synthetic_trace(args.requests, args.n_min, args.n_max, args.d,
                            seed=args.seed)
    warmup_stats = None
    if args.warmup:
        shapes = sorted({(q.shape[0], q.shape[1]) for q in trace})
        warmup_stats = srv.warmup(shapes)
    t0 = time.time()
    it = iter(trace)
    admitted = 0
    while admitted < len(trace) or srv.pending:
        for _ in range(args.arrivals_per_step):
            q = next(it, None)
            if q is None:
                break
            deadlined = args.deadline_frac > 0 and \
                (admitted % max(1, round(1 / args.deadline_frac))) == 0
            srv.submit(q, deadline_s=srv.now() + args.deadline_s
                       if deadlined else None,
                       priority=1 if deadlined else 0)
            admitted += 1
        srv.step()
    out = srv.stats()
    out["wall_s"] = round(time.time() - t0, 2)
    if warmup_stats is not None:
        out["warmup"] = warmup_stats
    out["schedules"] = {
        str(nb): [(r.survivors, r.num_refs)
                  for r in round_schedule(nb, args.budget_per_arm * nb)]
        for (nb, _) in sorted(srv.buckets_seen)}
    if session is not None:
        session.close()
    if args.metrics_out:
        with open(args.metrics_out, "w") as fh:
            fh.write(srv.exposition())
    print(json.dumps(out))


if __name__ == "__main__":
    main()
