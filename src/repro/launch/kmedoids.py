"""Bandit k-medoids driver — the clustering workload as a service entry.

Runs :func:`repro.api.kmedoids` (BUILD -> ragged per-cluster
refinement -> bandit SWAP) on a planted-cluster dataset, reports ARI against
the planted labels plus the full pull breakdown, and optionally compares
against exact PAM (``--compare``; pull ratio is always reported — exact
PAM's count is ``n^2`` by construction, no run needed). ``--serve`` routes
the refinement sweeps through the continuous-batching
:class:`repro.launch.serve_medoid.MedoidServer` instead of direct ragged
dispatches, sharing buckets with any other medoid traffic. ``--trace`` /
``--metrics-out`` attach the observability layer (:mod:`repro.obs`):
JSONL span/round/select events and a Prometheus text exposition.

Example:
  PYTHONPATH=src python -m repro.launch.kmedoids --k 8 --n 4096 --d 128 \
      --dataset rnaseq_like --backend pallas_fused
"""
from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax

from repro.api import KMedoidsConfig, kmedoids
from repro.cluster import adjusted_rand_index, pam_exact, pam_pulls
from repro.core import list_backends
from repro.data.medoid_datasets import CLUSTER_DATASETS


def run(n: int, d: int, k: int, dataset: str, *, metric: str = "",
        backend: str = "reference", seed: int = 0,
        build_budget_per_arm: int = 16, swap_budget_per_arm: int = 16,
        refine_budget_per_arm: int = 20, refine_sweeps: int = 1,
        max_swap_rounds: int = 8, compare: bool = False,
        serve: bool = False, trace=None,
        metrics_path: str | None = None) -> dict:
    if dataset not in CLUSTER_DATASETS:
        raise ValueError(f"unknown dataset {dataset!r}; "
                         f"one of {sorted(CLUSTER_DATASETS)}")
    ds_metric, gen = CLUSTER_DATASETS[dataset]
    metric = metric or ds_metric
    key = jax.random.key(seed)
    data, labels = gen(jax.random.fold_in(key, 0), n, d, k)

    cfg = KMedoidsConfig(metric=metric, backend=backend,
                         build_budget_per_arm=build_budget_per_arm,
                         swap_budget_per_arm=swap_budget_per_arm,
                         refine_budget_per_arm=refine_budget_per_arm,
                         refine_sweeps=refine_sweeps,
                         max_swap_rounds=max_swap_rounds)
    t0 = time.time()
    span = (trace.span("kmedoids", n=n, k=k, mode="serve" if serve
                       else "direct") if trace is not None
            else contextlib.nullcontext())
    srv = None
    with span:
        if serve:
            from repro.cluster import kmedoids_via_service
            from repro.launch.serve_medoid import MedoidServer
            # trace-aware server: refinement dispatches emit round/select
            # events (and run the telemetry program variant)
            srv = MedoidServer(metric=cfg.metric, backend=cfg.backend,
                               budget_per_arm=cfg.refine_budget_per_arm,
                               trace=trace)
            res, srv = kmedoids_via_service(
                data, k, jax.random.fold_in(key, 1), server=srv,
                metric=cfg.metric, backend=cfg.backend,
                build_budget_per_arm=cfg.build_budget_per_arm,
                swap_budget_per_arm=cfg.swap_budget_per_arm,
                refine_budget_per_arm=cfg.refine_budget_per_arm,
                refine_sweeps=cfg.refine_sweeps,
                max_swap_rounds=cfg.max_swap_rounds)
            serve_stats = srv.stats()
        else:
            res = kmedoids(data, k, jax.random.fold_in(key, 1), config=cfg)
            serve_stats = None
    wall = time.time() - t0

    out = {
        "n": n, "d": d, "k": k, "dataset": dataset, "metric": metric,
        "backend": backend, "mode": "serve" if serve else "direct",
        "medoids": res.medoids, "cost": round(res.cost, 3),
        "ari": round(adjusted_rand_index(res.labels, labels), 4),
        "pulls": res.pulls,
        "pulls_breakdown": {"build": res.build_pulls,
                            "assign": res.assign_pulls,
                            "refine": res.refine_pulls,
                            "swap": res.swap_pulls},
        "swaps": res.swaps, "refine_updates": res.refine_updates,
        "pam_pulls": pam_pulls(n),
        "pulls_ratio": round(pam_pulls(n) / max(1, res.pulls), 2),
        "wall_s": round(wall, 2),
    }
    if serve_stats is not None:
        out["serve"] = serve_stats
    if metrics_path:
        # --serve gets the per-bucket server metrics; the direct path still
        # has the engine odometers to expose
        from repro.obs import instrument_exposition
        with open(metrics_path, "w") as fh:
            fh.write(srv.exposition() if srv is not None
                     else instrument_exposition())
    if compare:
        t0 = time.time()
        pam = pam_exact(data, k, metric)
        out["pam"] = {
            "medoids": pam.medoids, "cost": round(pam.cost, 3),
            "ari": round(adjusted_rand_index(pam.labels, labels), 4),
            "swaps": pam.swaps, "wall_s": round(time.time() - t0, 2),
        }
        out["cost_vs_pam"] = round(res.cost / max(pam.cost, 1e-12), 4)
        out["ari_vs_pam"] = round(
            adjusted_rand_index(res.labels, pam.labels), 4)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--dataset", default="rnaseq_like",
                    choices=sorted(CLUSTER_DATASETS))
    ap.add_argument("--metric", default="",
                    choices=["", "l1", "l2", "sql2", "cosine"])
    ap.add_argument("--backend", default="reference",
                    choices=list(list_backends()))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--build-budget-per-arm", type=int, default=16)
    ap.add_argument("--swap-budget-per-arm", type=int, default=16)
    ap.add_argument("--refine-budget-per-arm", type=int, default=20)
    ap.add_argument("--refine-sweeps", type=int, default=1)
    ap.add_argument("--max-swap-rounds", type=int, default=8)
    ap.add_argument("--compare", action="store_true",
                    help="also run exact PAM (O(n^2) — keep n modest)")
    ap.add_argument("--serve", action="store_true",
                    help="route refinement through the MedoidServer")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compile cache directory (repeat "
                         "runs skip recompiling known program signatures)")
    ap.add_argument("--trace", default=None, metavar="PATH", dest="trace_out",
                    help="stream span/round/select events to this JSONL "
                         "file (with --serve, refinement dispatches run "
                         "with device-resident telemetry)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text exposition on exit (the "
                         "server's per-bucket metrics with --serve, the "
                         "engine odometers otherwise)")
    args = ap.parse_args(argv)
    if args.compile_cache:
        from repro.engine.programs import enable_persistent_cache
        enable_persistent_cache(args.compile_cache)
    session = None
    if args.trace_out:
        from repro.obs import TraceSession
        session = TraceSession(args.trace_out, meta={
            "workload": "kmedoids", "backend": args.backend,
            "n": args.n, "k": args.k, "seed": args.seed})
    try:
        print(json.dumps(run(
            args.n, args.d, args.k, args.dataset, metric=args.metric,
            backend=args.backend, seed=args.seed,
            build_budget_per_arm=args.build_budget_per_arm,
            swap_budget_per_arm=args.swap_budget_per_arm,
            refine_budget_per_arm=args.refine_budget_per_arm,
            refine_sweeps=args.refine_sweeps,
            max_swap_rounds=args.max_swap_rounds,
            compare=args.compare, serve=args.serve, trace=session,
            metrics_path=args.metrics_out)))
    finally:
        if session is not None:
            session.close()


if __name__ == "__main__":
    main()
