"""End-to-end training driver.

Runs real steps on whatever devices exist (CPU in this container, a pod in
production): builds the mesh from the live device count (elastic), shards
params/optimizer with the same partition rules the dry-run proves out at
512 chips, streams the deterministic data pipeline, checkpoints atomically,
auto-resumes, and records straggler statistics.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 50 --batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import manager as ckpt
from repro.configs import SHAPES, get_config, get_smoke_config
from repro.configs.base import InputShape
from repro.configs.registry import ARCH_NAMES
from repro.data.pipeline import DataCfg, batch_at
from repro.launch import partition
from repro.launch.mesh import logical_rules
from repro.models.model import build_model
from repro.models.sharding import logical_axis_rules
from repro.runtime.fault_tolerance import StepWatchdog, elastic_remesh, run_with_restarts
from repro.train.train_step import TrainCfg, TrainState, init_train_state, make_train_step


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          batch_size: int = 8, seq_len: int = 128, ckpt_dir: str | None = None,
          ckpt_every: int = 20, tcfg: TrainCfg | None = None,
          grad_compression: bool = False, log_every: int = 10) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shape = InputShape("custom", seq_len, batch_size, "train")
    model = build_model(cfg)
    tcfg = tcfg or TrainCfg(peak_lr=1e-3, warmup_steps=max(2, steps // 10),
                            total_steps=steps, remat=True,
                            grad_compression=grad_compression)

    mesh = elastic_remesh(preferred_tp=min(16, len(jax.devices())))
    rules = logical_rules(mesh)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspecs = partition.param_specs(params_shape, cfg, mesh)

    state_shape = jax.eval_shape(
        lambda: init_train_state(model, jax.random.key(0), tcfg))
    state_specs = type(state_shape)(
        params=pspecs,
        opt=type(state_shape.opt)(step=P(), mu=pspecs, nu=pspecs),
        ef=None if state_shape.ef is None else type(state_shape.ef)(error=pspecs),
        step=P(),
    )
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)

    step_fn = make_train_step(model, tcfg)

    def wrapped(state, batch):
        with logical_axis_rules(rules):
            return step_fn(state, batch)

    batch0 = batch_at(cfg, shape, 0, DataCfg())
    bspecs = partition.batch_specs(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0),
        mesh)
    jitted = jax.jit(wrapped, in_shardings=(ns(state_specs), ns(bspecs)),
                     out_shardings=(ns(state_specs), None), donate_argnums=(0,))

    # ---- init or resume -----------------------------------------------------
    start_step = 0
    with mesh:
        state = init_train_state(model, jax.random.key(42), tcfg)
        state = jax.device_put(state, ns(state_specs))
        if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
            state, meta = ckpt.restore(ckpt_dir, state_shape,
                                       shardings=ns(state_specs))
            start_step = meta["step"]
            print(f"# resumed from step {start_step}", file=sys.stderr)

    watchdog = StepWatchdog()
    losses = []

    def do_step(t: int) -> int:
        nonlocal state
        b = jax.device_put(batch_at(cfg, shape, t, DataCfg()), ns(bspecs))
        t0 = time.time()
        with mesh:
            state, metrics = jitted(state, b)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        straggler = watchdog.record(dt)
        losses.append(loss)
        if t % log_every == 0:
            print(json.dumps({"step": t, "loss": round(loss, 4),
                              "sec": round(dt, 3),
                              "straggler": straggler}), file=sys.stderr)
        if ckpt_dir and (t + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, t + 1, state)
        return t + 1

    def on_restart(step_, exc):
        nonlocal state
        print(f"# restart after {type(exc).__name__} at step {step_}",
              file=sys.stderr)
        if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
            with mesh:
                st, meta = ckpt.restore(ckpt_dir, state_shape,
                                        shardings=ns(state_specs))
            state = st
            return meta["step"]
        return step_

    run_with_restarts(do_step, start_step=start_step, total_steps=steps,
                      on_restart=on_restart)
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, state)
    return {"final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None,
            "stragglers": watchdog.stragglers, "steps": steps}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args(argv)
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch_size=args.batch, seq_len=args.seq_len,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                grad_compression=args.grad_compression)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
