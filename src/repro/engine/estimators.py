"""Arm-loss estimators: how a batch of reference pulls scores each arm.

The estimator is the extension axis of the unified engine. The round loop
(:func:`repro.engine.halving.run_halving`) owns reference draws, masking,
halving, and selection; an :class:`ArmEstimator` owns only the mapping

    (candidate rows (C, d), reference rows (R, d)) -> per-arm raw sums (C,)

plus an optional auxiliary output (any pytree) that the engine threads
through to the outcome — the SWAP estimator returns its ``(C, k)``
per-medoid delta block this way. Sums are *pre-division*: the engine
normalizes by the (static) reference count, or the drawn valid count under a
``ref_mask``, so estimators never reimplement ragged denominators.

Built-in estimators (the three bandit workloads of BanditPAM/BanditPAM++):

``medoid_centrality``
    ``sum_j d(x_i, y_j)`` — the paper's problem. Rides the backend's fused
    centrality kernels when available (no ``(C, R)`` block in HBM).
``build_delta``
    BanditPAM BUILD: ``sum_j min(d1_j, d(x_i, y_j))`` against the cached
    nearest-medoid distance ``d1``.
``swap_delta``
    FasterPAM SWAP: one shared draw prices all k swaps of every candidate
    via a ``(C, t)`` block + ``(t, k)`` one-hot segment sum; the arm value
    is ``min_i delta(c, i)`` and the full delta block is the aux output.

A backend can register a fused implementation of any estimator in its
``fused_estimators`` mapping (next to ``centrality_sums`` — see
:class:`repro.core.backend.DistanceBackend`); the factories below pick it up
automatically, so a new Pallas kernel for, say, ``build_delta`` plugs in
without touching any engine or workload code. Third-party estimators
register by name via :func:`register_estimator` (see the README's
trimmed-mean example).

**Scan-body-safe contract** (required since the round loop became a
``lax.scan``): ``score`` must be a pure traced function of its array inputs
— no host round-trips (item / host-array conversion / device fetches), no
branching on concrete array *values*, no reliance on the number of rounds.
``ref_mask``, when given, is a float *weight* vector over the reference
axis and must enter multiplicatively (weight-0 references contribute
exactly nothing to the sums): inside a scan band the engine passes
positional validity (``position < t_r``) as weights over a fixed-width
reference buffer, so any non-multiplicative mask handling would corrupt
every scanned round. ``aux`` is only consumed from the *output* round (the
engine discards it in scanned rounds), so it may be arbitrarily large.
All built-in estimators and the fused Pallas paths satisfy this contract.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

# NOTE: repro.core is imported lazily inside the factories — the engine
# package sits BELOW repro.core in the layering (repro.core.__init__ pulls in
# corr_sh, which is built on this package), so module-level imports here
# would be circular. Factories run at trace time only; the cost is nil.

# score(cand_rows, ref_rows, *, refs, ref_mask=None) -> (sums (C,), aux).
# ``refs`` are the drawn global reference indices (for gathering cached
# per-point state like d1/d2/nearest); ``ref_mask`` is the (R,) float
# validity mask over the drawn references, or None on the dense path.
ScoreFn = Callable[..., Tuple[jnp.ndarray, Any]]


@dataclass(frozen=True)
class ArmEstimator:
    """One arm-loss estimator: a name (for registries/telemetry) + score fn."""
    name: str
    score: ScoreFn


# ------------------------- estimator factory registry -----------------------

# name -> factory(backend, metric, **params) -> ArmEstimator
_ESTIMATORS: dict[str, Callable[..., ArmEstimator]] = {}


def register_estimator(name: str, factory: Callable[..., ArmEstimator],
                       ) -> Callable[..., ArmEstimator]:
    """Register an estimator factory (last registration wins on a name)."""
    _ESTIMATORS[name] = factory
    return factory


def get_estimator(name: str) -> Callable[..., ArmEstimator]:
    try:
        return _ESTIMATORS[name]
    except KeyError:
        raise ValueError(f"unknown estimator {name!r}; "
                         f"one of {list_estimators()}") from None


def list_estimators() -> tuple[str, ...]:
    return tuple(sorted(_ESTIMATORS))


# --------------------------- masked-call resolution -------------------------

def _masked_centrality_fn(be, fn, metric: str) -> Callable:
    """Mask-aware form of a backend centrality fn: built-in backends take
    ``ref_mask`` natively (the fused kernels apply it in VMEM); a registered
    backend that predates the keyword falls back to masking its pairwise
    block."""
    from repro.core import distances

    try:
        params = inspect.signature(fn).parameters
        mask_native = "ref_mask" in params or any(
            p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())
    except (TypeError, ValueError):   # builtins / odd callables: probe-free
        mask_native = False
    if mask_native:
        return lambda x, y, m: fn(x, y, ref_mask=m)
    pw = be.pairwise(metric)
    return lambda x, y, m: distances.masked_rowsum(pw(x, y), m)


# ----------------------------- built-in factories ---------------------------

def medoid_centrality(backend=None, metric: str = "l2", *,
                      pairwise_fn: Optional[Callable] = None) -> ArmEstimator:
    """The paper's estimator: ``sum_j d(x_i, y_j)``.

    Uses the backend's fused path when registered (``fused_estimators`` or
    the fused ``centrality_sums`` kernels). ``pairwise_fn`` overrides the
    distance block directly (the legacy hook of
    ``correlated_sequential_halving``; takes precedence over ``backend``).
    """
    from repro.core import distances
    from repro.core.backend import get_backend

    if pairwise_fn is not None:
        def plain(x, y):
            return jnp.sum(pairwise_fn(x, y), axis=1)

        def masked(x, y, m):
            return distances.masked_rowsum(pairwise_fn(x, y), m)
    else:
        be = get_backend(backend)
        fused = be.fused_estimators.get("medoid_centrality")
        fn = fused(metric) if fused is not None else be.centrality_sums(metric)
        plain = fn
        masked = _masked_centrality_fn(be, fn, metric)

    def score(cand, ref_rows, *, refs, ref_mask=None):
        if ref_mask is None:
            return plain(cand, ref_rows), None
        return masked(cand, ref_rows, ref_mask), None

    return ArmEstimator("medoid_centrality", score)


def build_delta(backend=None, metric: str = "l2", *,
                d1: jnp.ndarray) -> ArmEstimator:
    """BanditPAM BUILD estimator: ``sum_j min(d1_j, d(x_i, y_j))`` — the
    cached nearest-medoid distance ``d1`` caps every reference's
    contribution, so an arm's value is the total cost were it added as the
    next medoid (up to the constant ``sum_j d1_j``)."""
    from repro.core import distances
    from repro.core.backend import get_backend

    be = get_backend(backend)
    fused = be.fused_estimators.get("build_delta")
    if fused is not None:
        fn = fused(metric)

        def score(cand, ref_rows, *, refs, ref_mask=None):
            return fn(cand, ref_rows, d1[refs], ref_mask=ref_mask), None
    else:
        pw = be.pairwise(metric)

        def score(cand, ref_rows, *, refs, ref_mask=None):
            blk = jnp.minimum(pw(cand, ref_rows), d1[refs][None, :])
            return distances.masked_rowsum(blk, ref_mask), None

    return ArmEstimator("build_delta", score)


def swap_delta(backend=None, metric: str = "l2", *, d1: jnp.ndarray,
               d2: jnp.ndarray, nearest: jnp.ndarray, k: int) -> ArmEstimator:
    """FasterPAM SWAP estimator. Per candidate c and medoid slot i, over a
    shared reference draw J:

        delta(c, i) = sum_{j in J} min(d(c,j) - d1_j, 0)
                    + sum_{j in J, nearest_j = i} [ min(d(c,j), d2_j) - d1_j
                                                    - min(d(c,j) - d1_j, 0) ]

    (a (C, t) block, a (t, k) one-hot segment sum — entirely on-device).
    The arm value is ``min_i delta(c, i)``; the full ``(C, k)`` delta block
    is returned as aux so the winner's slot falls out after the loop."""
    from repro.core.backend import get_backend

    be = get_backend(backend)
    fused = be.fused_estimators.get("swap_delta")
    if fused is not None:
        fn = fused(metric)

        def score(cand, ref_rows, *, refs, ref_mask=None):
            delta = fn(cand, ref_rows, d1[refs], d2[refs], nearest[refs],
                       k, ref_mask=ref_mask)
            return jnp.min(delta, axis=1), delta
    else:
        pw = be.pairwise(metric)

        def score(cand, ref_rows, *, refs, ref_mask=None):
            blk = pw(cand, ref_rows)                          # (C, t)
            d1r, d2r = d1[refs][None, :], d2[refs][None, :]
            gain = jnp.minimum(blk - d1r, 0.0)                # (C, t)
            term = jnp.minimum(blk, d2r) - d1r - gain         # (C, t)
            if ref_mask is not None:
                m = ref_mask.reshape(-1).astype(blk.dtype)[None, :]
                gain = gain * m
                term = term * m
            onehot = jax.nn.one_hot(nearest[refs], k, dtype=blk.dtype)
            delta = (jnp.sum(gain, axis=1, keepdims=True)
                     + term @ onehot)                         # (C, k)
            return jnp.min(delta, axis=1), delta

    return ArmEstimator("swap_delta", score)


register_estimator("medoid_centrality", medoid_centrality)
register_estimator("build_delta", build_delta)
register_estimator("swap_delta", swap_delta)
