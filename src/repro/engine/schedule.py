"""The paper's deterministic round schedule (shared by every workload).

Given ``(n, budget)``, the per-round sizes

    s_r  = |S_r|   (number of surviving arms)
    t_r  = clip(floor(budget / (s_r * ceil(log2 n))), 1, n)

are *deterministic Python integers* — so every round's score block
``(s_r, t_r)`` has a static shape and any algorithm built on the schedule
traces into a single XLA program (the Python loop over rounds unrolls). No
dynamic shapes, no host round-trips, no data-dependent control flow except
the final ``t_r == n`` exact-output branch, which is also static.

This module was split out of ``repro.core.corr_sh`` when the round loop
itself moved into :mod:`repro.engine.halving`; the names are still
re-exported from :mod:`repro.core` unchanged.
"""
from __future__ import annotations

import math
from collections.abc import Sequence
from dataclasses import dataclass


@dataclass(frozen=True)
class Round:
    """Static per-round schedule entry."""
    survivors: int   # s_r going *into* the round
    num_refs: int    # t_r
    exact: bool      # t_r == n -> estimates are exact, output now

    @property
    def pulls(self) -> int:
        return self.survivors * self.num_refs


def round_schedule(n: int, budget: int) -> list[Round]:
    """The paper's deterministic round schedule for (n, budget)."""
    if n < 1:
        raise ValueError("need at least one point")
    if n == 1:
        return []
    log2n = max(1, math.ceil(math.log2(n)))
    rounds: list[Round] = []
    s = n
    for _ in range(log2n):
        t = min(max(budget // (s * log2n), 1), n)
        exact = t >= n
        rounds.append(Round(survivors=s, num_refs=t, exact=exact))
        if exact or s <= 1:
            break
        s = math.ceil(s / 2)
        if s == 1:
            break
    return rounds


# ---------------------------------------------------------------------------
# stacked (scan-ready) schedule form
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StackedBand:
    """A contiguous run of rounds executed as ONE ``lax.scan`` at one static
    buffer shape.

    ``width`` is the survivor-buffer width (the arm count *entering* the
    band's first round) and ``ref_cap`` the reference-buffer width (the
    largest ``t_r`` in the band); within the band, the per-round live counts
    ``survivors``/``num_refs`` are applied as positional masks over those
    fixed-width buffers. Banding bounds the fixed-width compute overhead: a
    band of B halving rounds scores at most ``(2^B - 1)/B`` times the
    scheduled pulls of its rounds, while the scan body compiles once per
    band instead of once per round.
    """
    start: int                     # index of the band's first round
    width: int                     # static survivor-buffer width
    ref_cap: int                   # static reference-buffer width
    survivors: tuple[int, ...]     # live arm count entering each round
    num_refs: tuple[int, ...]      # t_r per round

    def __len__(self) -> int:
        return len(self.num_refs)


@dataclass(frozen=True)
class StackedSchedule:
    """Array form of a schedule for ``n`` arms: the scanned prefix (bands
    over rounds ``[0, r_stop)``) plus the static output round ``r_stop``.

    ``sizes[r]`` is the number of arms *entering* round r (``sizes[0] == n``,
    then ``ceil(size/2)`` per halving — the exact sizes the pre-scan Python
    loop materialized), so ``sizes[r_stop]`` is the static width of the
    output round's survivor set.
    """
    bands: tuple[StackedBand, ...]
    r_stop: int
    sizes: tuple[int, ...]


@dataclass(frozen=True)
class Schedule(Sequence):
    """A round schedule plus its scan-ready ``stacked()`` array form."""
    rounds: tuple[Round, ...]

    @classmethod
    def from_budget(cls, n: int, budget: int) -> "Schedule":
        return cls(tuple(round_schedule(n, budget)))

    def __len__(self) -> int:
        return len(self.rounds)

    def __getitem__(self, i):
        return self.rounds[i]

    @property
    def pulls(self) -> int:
        return sum(r.pulls for r in self.rounds)

    def stacked(self, n: int, *, band_rounds: int = 3,
                slack: int = 1) -> StackedSchedule:
        """Band the schedule for an ``n``-arm problem (see
        :class:`StackedBand`). ``band_rounds`` caps rounds per band (the
        compile-vs-compute knob: 1 = per-round shapes, no waste; large =
        one scan body, up to ``2^B/B``-fold extra scored pulls).

        ``slack > 1`` inflates every band's buffer width to
        ``min(n, slack * sizes[start])`` — headroom for margin-widened
        halving (``run_halving(widen=...)``), where a round may keep more
        than ``sizes[r+1]`` arms. The per-round scheduled live counts are
        unchanged; only the static buffer shapes grow.
        """
        if band_rounds < 1:
            raise ValueError(f"band_rounds must be >= 1, got {band_rounds}")
        if slack < 1:
            raise ValueError(f"slack must be >= 1, got {slack}")
        if not self.rounds:
            raise ValueError("empty schedule has no stacked form")
        sizes = [int(n)]
        for _ in self.rounds[:-1]:
            sizes.append(math.ceil(sizes[-1] / 2))
        r_stop = len(self.rounds) - 1
        for r, rd in enumerate(self.rounds):
            if rd.exact or sizes[r] <= 2:
                r_stop = r
                break
        bands = []
        for start in range(0, r_stop, band_rounds):
            stop = min(start + band_rounds, r_stop)
            bands.append(StackedBand(
                start=start,
                width=min(int(n), slack * sizes[start]),
                ref_cap=max(rd.num_refs for rd in self.rounds[start:stop]),
                survivors=tuple(sizes[start:stop]),
                num_refs=tuple(rd.num_refs
                               for rd in self.rounds[start:stop])))
        return StackedSchedule(bands=tuple(bands), r_stop=r_stop,
                               sizes=tuple(sizes))


def as_schedule(schedule) -> Schedule:
    """Coerce a ``Sequence[Round]`` (or ``Schedule``) to a :class:`Schedule`."""
    if isinstance(schedule, Schedule):
        return schedule
    return Schedule(tuple(schedule))


def stop_round(schedule: list[Round]) -> int:
    """Index of the round that produces the output: the first exact round or
    the first with <= 2 survivors (both static properties of the schedule —
    the engine's early-out branch never depends on data)."""
    for r, rd in enumerate(schedule):
        if rd.exact or rd.survivors <= 2:
            return r
    return len(schedule) - 1


def schedule_pulls(n: int, budget: int) -> int:
    """Total distance computations the schedule will actually perform."""
    return sum(r.pulls for r in round_schedule(n, budget))
