"""The paper's deterministic round schedule (shared by every workload).

Given ``(n, budget)``, the per-round sizes

    s_r  = |S_r|   (number of surviving arms)
    t_r  = clip(floor(budget / (s_r * ceil(log2 n))), 1, n)

are *deterministic Python integers* — so every round's score block
``(s_r, t_r)`` has a static shape and any algorithm built on the schedule
traces into a single XLA program (the Python loop over rounds unrolls). No
dynamic shapes, no host round-trips, no data-dependent control flow except
the final ``t_r == n`` exact-output branch, which is also static.

This module was split out of ``repro.core.corr_sh`` when the round loop
itself moved into :mod:`repro.engine.halving`; the names are still
re-exported from :mod:`repro.core` unchanged.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Round:
    """Static per-round schedule entry."""
    survivors: int   # s_r going *into* the round
    num_refs: int    # t_r
    exact: bool      # t_r == n -> estimates are exact, output now

    @property
    def pulls(self) -> int:
        return self.survivors * self.num_refs


def round_schedule(n: int, budget: int) -> list[Round]:
    """The paper's deterministic round schedule for (n, budget)."""
    if n < 1:
        raise ValueError("need at least one point")
    if n == 1:
        return []
    log2n = max(1, math.ceil(math.log2(n)))
    rounds: list[Round] = []
    s = n
    for _ in range(log2n):
        t = min(max(budget // (s * log2n), 1), n)
        exact = t >= n
        rounds.append(Round(survivors=s, num_refs=t, exact=exact))
        if exact or s <= 1:
            break
        s = math.ceil(s / 2)
        if s == 1:
            break
    return rounds


def stop_round(schedule: list[Round]) -> int:
    """Index of the round that produces the output: the first exact round or
    the first with <= 2 survivors (both static properties of the schedule —
    the engine's early-out branch never depends on data)."""
    for r, rd in enumerate(schedule):
        if rd.exact or rd.survivors <= 2:
            return r
    return len(schedule) - 1


def schedule_pulls(n: int, budget: int) -> int:
    """Total distance computations the schedule will actually perform."""
    return sum(r.pulls for r in round_schedule(n, budget))
