"""Engine-wide dispatch/trace odometers.

PR 2's ragged engine carried a single module-global compile counter
(``_RAGGED_TRACES``) so tests and benchmarks could assert the bucketing
invariant ("mixed-n traffic compiles at most one program per bucket"). This
module generalizes that into one small instrument shared by every jitted
engine entry point (:mod:`repro.engine.programs`):

* ``note_trace(kind)`` — called *inside* a jitted body, so it runs exactly
  once per XLA program traced for that entry point (retraces for new shapes
  count; cached same-shape calls don't);
* ``note_dispatch(kind)`` — called in the host-side wrapper, once per call.

Both are monotone odometers (never reset): consumers assert on *deltas*,
so independent test files and servers can't clobber each other. The
steady-state claim of the one-program refactor — "repeated same-shape calls
never retrace" — is exactly ``trace delta == 0`` while ``dispatch delta``
grows, and ``counters()`` emits the full snapshot into ``BENCH_engine.json``
so the dispatch-bound -> compute-bound shift is visible per PR.
"""
from __future__ import annotations

from collections import Counter

_TRACES: Counter = Counter()
_DISPATCHES: Counter = Counter()


def note_trace(kind: str) -> None:
    """Record one XLA trace of the ``kind`` entry point (call at trace time,
    i.e. from inside the jitted body)."""
    _TRACES[kind] += 1


def note_dispatch(kind: str) -> None:
    """Record one host-side call into the ``kind`` entry point."""
    _DISPATCHES[kind] += 1


def trace_count(kind: str | None = None) -> int:
    """Programs traced so far — for ``kind``, or in total."""
    return _TRACES[kind] if kind is not None else sum(_TRACES.values())


def dispatch_count(kind: str | None = None) -> int:
    """Dispatches so far — for ``kind``, or in total."""
    return _DISPATCHES[kind] if kind is not None else sum(_DISPATCHES.values())


def counters() -> dict:
    """Snapshot of both odometers (per kind), for benchmark emission."""
    return {"traces": dict(sorted(_TRACES.items())),
            "dispatches": dict(sorted(_DISPATCHES.items()))}
