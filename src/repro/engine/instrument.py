"""Engine-wide dispatch/trace odometers.

PR 2's ragged engine carried a single module-global compile counter
(``_RAGGED_TRACES``) so tests and benchmarks could assert the bucketing
invariant ("mixed-n traffic compiles at most one program per bucket"). This
module generalizes that into one small instrument shared by every jitted
engine entry point (:mod:`repro.engine.programs`):

* ``note_trace(kind)`` — called *inside* a jitted body, so it runs exactly
  once per XLA program traced for that entry point (retraces for new shapes
  count; cached same-shape calls don't);
* ``note_dispatch(kind)`` — called in the host-side wrapper, once per call.

Both are monotone odometers (never reset): consumers assert on *deltas*,
so independent test files and servers can't clobber each other. The
steady-state claim of the one-program refactor — "repeated same-shape calls
never retrace" — is exactly ``trace delta == 0`` while ``dispatch delta``
grows, and ``counters()`` emits the full snapshot into ``BENCH_engine.json``
so the dispatch-bound -> compute-bound shift is visible per PR.
"""
from __future__ import annotations

from collections import Counter

_TRACES: Counter = Counter()
_DISPATCHES: Counter = Counter()


def note_trace(kind: str) -> None:
    """Record one XLA trace of the ``kind`` entry point (call at trace time,
    i.e. from inside the jitted body)."""
    _TRACES[kind] += 1


def note_dispatch(kind: str) -> None:
    """Record one host-side call into the ``kind`` entry point."""
    _DISPATCHES[kind] += 1


def trace_count(kind: str | None = None) -> int:
    """Programs traced so far — for ``kind``, or in total."""
    return _TRACES[kind] if kind is not None else sum(_TRACES.values())


def dispatch_count(kind: str | None = None) -> int:
    """Dispatches so far — for ``kind``, or in total."""
    return _DISPATCHES[kind] if kind is not None else sum(_DISPATCHES.values())


def counters() -> dict:
    """Snapshot of both odometers (per kind), for benchmark emission."""
    return {"traces": dict(sorted(_TRACES.items())),
            "dispatches": dict(sorted(_DISPATCHES.items()))}


class deltas:
    """Context helper over the monotone odometers: snapshot on enter, deltas
    on demand — so consumers stop hand-rolling ``before = trace_count(...)``
    / ``after - before`` arithmetic::

        with instrument.deltas() as d:
            find_medoid(data, key)
        assert d.trace("medoid") <= 1      # programs traced inside the block
        assert d.dispatch("medoid") == 1   # dispatches inside the block

    Deltas are readable both mid-block and after exit (exit freezes them, so
    work done later never contaminates a recorded measurement). ``counters()``
    returns the per-kind nonzero deltas in the same shape as the module-level
    :func:`counters` snapshot — that per-block form is what benchmark cells
    emit, keeping ``BENCH_*.json`` rows independent of execution order.
    """

    def __enter__(self) -> "deltas":
        self._t0 = Counter(_TRACES)
        self._d0 = Counter(_DISPATCHES)
        self._t1 = self._d1 = None
        return self

    def __exit__(self, *exc) -> None:
        self._t1 = Counter(_TRACES)
        self._d1 = Counter(_DISPATCHES)

    def _now(self) -> tuple[Counter, Counter]:
        if self._t1 is not None:
            return self._t1, self._d1
        return _TRACES, _DISPATCHES

    def trace(self, kind: str | None = None) -> int:
        """Programs traced since enter — for ``kind``, or in total."""
        cur, _ = self._now()
        if kind is not None:
            return cur[kind] - self._t0[kind]
        return sum(cur.values()) - sum(self._t0.values())

    def dispatch(self, kind: str | None = None) -> int:
        """Dispatches since enter — for ``kind``, or in total."""
        _, cur = self._now()
        if kind is not None:
            return cur[kind] - self._d0[kind]
        return sum(cur.values()) - sum(self._d0.values())

    def counters(self) -> dict:
        """Per-kind nonzero deltas, same shape as the module snapshot."""
        t, d = self._now()
        return {"traces": {k: v for k, v in sorted((t - self._t0).items())},
                "dispatches": {k: v
                               for k, v in sorted((d - self._d0).items())}}
