"""Cached jitted entry points: every workload dispatches one XLA program.

This is the execution layer of the one-program refactor. Each ``*_program``
factory returns a jitted callable closed over its static configuration
(``budget``/``metric``/``backend``/bucket), memoized in a module-level
table — so the facade (:mod:`repro.api`), the serving layer, and the
clustering pipeline all share literally the same compiled programs, keyed by
``(kind, schedule config, backend, donation, telemetry)`` plus jax's own
shape key. Repeated same-shape calls never retrace (asserted counter-based
in ``tests/test_oneprogram.py`` via :mod:`repro.engine.instrument`); a
telemetry-carrying variant is its own cached program (more outputs), so
turning telemetry on costs one extra trace per signature — once — and
nothing per call thereafter.

**Buffer donation**: pass ``donate=True`` to donate the arm buffer
(argument 0) to the program — correct only when the caller owns the buffer
and never touches it again (the facade enables it for buffers *it* packed;
user-passed arrays are never donated). On backends without donation support
(CPU) the flag is folded away so a donating and non-donating caller share
one program instead of compiling twice; :func:`donation_enabled` reports
the effective behavior.

**Persistent compile cache**: :func:`enable_persistent_cache` points jax's
compilation cache at a directory (thresholds dropped to cache-everything),
so a restarted server re-*traces* known buckets but never re-*compiles*
them. The ``JAX_COMPILATION_CACHE_DIR`` env var is jax's native equivalent.
"""
from __future__ import annotations

import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.engine import instrument
from repro.engine.estimators import medoid_centrality
from repro.engine.halving import HalvingProblem, resolve_order_fn, run_halving
from repro.engine.schedule import round_schedule
from repro.obs import telemetry as obs_telemetry

_PROGRAMS: dict[tuple, Callable] = {}


def donation_enabled() -> bool:
    """Whether buffer donation actually takes effect on this backend (jax
    silently ignores donations on CPU; we fold the flag away there so the
    donating and plain paths share one compiled program)."""
    return jax.default_backend() not in ("cpu",)


def program_cache_info() -> dict:
    """Snapshot of the program table: kind -> number of cached callables."""
    info: dict[str, int] = {}
    for key in _PROGRAMS:
        info[key[0]] = info.get(key[0], 0) + 1
    return dict(sorted(info.items()))


def _memo(key: tuple, build: Callable[[], Callable]) -> Callable:
    fn = _PROGRAMS.get(key)
    if fn is None:
        fn = _PROGRAMS[key] = build()
    return fn


# ------------------------------ medoid programs -----------------------------

def _quant_config(precision: str, error_model: str, backend: str):
    """Resolve (effective backend, normalized error model) for a precision.

    For ``precision="fp32"`` the error model is folded to ``None`` so every
    fp32 caller shares one cached program regardless of its quant settings;
    otherwise the quantized backend replaces the caller's (a fused base
    backend keeps a fused quantized path — see ``repro.quant.backends``).
    Imports :mod:`repro.quant` lazily: the engine sits below it in layering.
    """
    if precision == "fp32":
        return backend, None
    from repro import quant

    return quant.backend_for(precision, base=backend), error_model


def medoid_program(*, budget: int, metric: str = "l2",
                   backend: str = "reference", donate: bool = False,
                   telemetry: bool = False, precision: str = "fp32",
                   error_model: str = "probe") -> Callable:
    """Jitted single-query medoid: ``(data (n, d), key) -> scalar index`` —
    or ``(index, telemetry dict)`` with ``telemetry`` (the per-round buffer
    of :mod:`repro.obs.telemetry` rides the same single program).

    With ``precision`` in {"bf16", "int8"} the whole pipeline changes:
    distances run through the quantized backend, halving runs margin-widened
    (``widen`` from the ``error_model`` of :mod:`repro.quant.error`, traced
    into the same program), and the finalists are re-scored in exact fp32
    (:func:`repro.quant.verify.exact_winner`) — the program returns
    ``(index, verified)`` (plus telemetry), where ``verified`` is the traced
    margin-capacity certificate."""
    eff_donate = donate and donation_enabled()
    eff_backend, eff_err = _quant_config(precision, error_model, backend)

    def build():
        def impl(data: jnp.ndarray, key: jax.Array):
            instrument.note_trace("medoid")
            rounds = round_schedule(data.shape[0], budget)
            if precision == "fp32":
                if not rounds:                    # n == 1
                    winner = jnp.zeros((), jnp.int32)
                    return (winner, obs_telemetry.empty()) if telemetry \
                        else winner
                problem = HalvingProblem(
                    data, medoid_centrality(eff_backend, metric))
                out = run_halving(problem, rounds, eff_backend, key=key,
                                  telemetry=telemetry)
                return (out.winner, out.telemetry) if telemetry \
                    else out.winner
            from repro import quant

            if not rounds:                        # n == 1: trivially exact
                winner = jnp.zeros((), jnp.int32)
                verified = jnp.ones((), bool)
                return (winner, verified, obs_telemetry.empty()) \
                    if telemetry else (winner, verified)
            problem = HalvingProblem(
                data, medoid_centrality(eff_backend, metric))
            widen = quant.margin(data, metric, precision, model=eff_err)
            out = run_halving(problem, rounds, eff_backend, key=key,
                              telemetry=telemetry, widen=widen)
            winner, verified = quant.exact_winner(problem, out, metric)
            return (winner, verified, out.telemetry) if telemetry \
                else (winner, verified)
        return jax.jit(impl, donate_argnums=(0,) if eff_donate else ())

    return _memo(("medoid", budget, metric, eff_backend, eff_donate,
                  telemetry, precision, eff_err), build)


def batch_program(*, budget: int, metric: str = "l2",
                  backend: str = "reference", donate: bool = False,
                  telemetry: bool = False, precision: str = "fp32",
                  error_model: str = "probe") -> Callable:
    """Jitted batched medoid: ``(data (B, n, d), key) -> (B,) indices`` —
    or ``((B,) indices, telemetry)`` with ``telemetry`` (per-query rows,
    leaves ``(B, R)``; the shared static schedule columns broadcast).

    One shared static round schedule, per-query reference draws (the key is
    split per query); the whole batch is a single vmap of the scanned round
    loop — one XLA program, one dispatch. Quantized (``precision != "fp32"``)
    programs vmap the widened run + exact fp32 verification per query and
    return ``((B,) indices, (B,) verified[, telemetry])`` — see
    :func:`medoid_program`.
    """
    eff_donate = donate and donation_enabled()
    eff_backend, eff_err = _quant_config(precision, error_model, backend)

    def build():
        def impl(data: jnp.ndarray, key: jax.Array):
            instrument.note_trace("batch")
            if data.ndim != 3:
                raise ValueError(f"expected (B, n, d) batch, "
                                 f"got shape {data.shape}")
            b, n, _ = data.shape
            rounds = round_schedule(n, budget)
            keys = jax.random.split(key, b)
            if not rounds:                        # n == 1
                winners = jnp.zeros((b,), jnp.int32)
                outs = (winners,) if precision == "fp32" \
                    else (winners, jnp.ones((b,), bool))
                if telemetry:
                    outs = outs + (jax.tree_util.tree_map(
                        lambda x: jnp.broadcast_to(x, (b,) + x.shape),
                        obs_telemetry.empty()),)
                return outs[0] if len(outs) == 1 else outs
            est = medoid_centrality(eff_backend, metric)
            order_fn = resolve_order_fn(eff_backend)

            def one(x: jnp.ndarray, k: jax.Array):
                problem = HalvingProblem(x, est)
                if precision == "fp32":
                    out = run_halving(problem, rounds, key=k,
                                      survivor_order=order_fn,
                                      telemetry=telemetry)
                    return (out.winner, out.telemetry) if telemetry \
                        else out.winner
                from repro import quant

                widen = quant.margin(x, metric, precision, model=eff_err)
                out = run_halving(problem, rounds, key=k,
                                  survivor_order=order_fn,
                                  telemetry=telemetry, widen=widen)
                winner, verified = quant.exact_winner(problem, out, metric)
                return (winner, verified, out.telemetry) if telemetry \
                    else (winner, verified)

            return jax.vmap(one)(data, keys)
        return jax.jit(impl, donate_argnums=(0,) if eff_donate else ())

    return _memo(("batch", budget, metric, eff_backend, eff_donate,
                  telemetry, precision, eff_err), build)


def ragged_program(*, n_bucket: int, budget: int, metric: str = "l2",
                   backend: str = "reference", donate: bool = False,
                   telemetry: bool = False, precision: str = "fp32",
                   error_model: str = "probe") -> Callable:
    """Jitted ragged medoid: ``(data (B, n_bucket, d), lengths (B,), key) ->
    (B,) indices`` — or ``((B,) indices, telemetry)`` with ``telemetry``
    (leaves ``(B, R)``; the measured rows differ per query through its
    ``alive`` count and masked estimates, the schedule columns are the
    bucket's and broadcast). Padded arms are masked out of every round (arm
    and reference roles both); a query filling its bucket is bit-identical
    to the single-query program. Quantized programs additionally return the
    per-query ``(B,) verified`` certificate — see :func:`medoid_program`."""
    eff_donate = donate and donation_enabled()
    eff_backend, eff_err = _quant_config(precision, error_model, backend)

    def build():
        def impl(data: jnp.ndarray, lengths: jnp.ndarray,
                 key: jax.Array):
            instrument.note_trace("ragged")
            b = data.shape[0]
            rounds = round_schedule(n_bucket, budget)
            if not rounds:                        # n_bucket == 1
                winners = jnp.zeros((b,), jnp.int32)
                outs = (winners,) if precision == "fp32" \
                    else (winners, jnp.ones((b,), bool))
                if telemetry:
                    outs = outs + (jax.tree_util.tree_map(
                        lambda x: jnp.broadcast_to(x, (b,) + x.shape),
                        obs_telemetry.empty()),)
                return outs[0] if len(outs) == 1 else outs
            valid = (jnp.arange(n_bucket, dtype=jnp.int32)[None, :]
                     < lengths[:, None])
            keys = jax.random.split(key, b)
            est = medoid_centrality(eff_backend, metric)
            order_fn = resolve_order_fn(eff_backend)

            def one(x: jnp.ndarray, v: jnp.ndarray, k: jax.Array):
                # padded arms: ineligible to win (arm_mask) AND dropped from
                # every reference draw / denominator (ref_mask) — one
                # validity mask plays both roles.
                problem = HalvingProblem(x, est, arm_mask=v, ref_mask=v)
                if precision == "fp32":
                    out = run_halving(problem, rounds, key=k,
                                      survivor_order=order_fn,
                                      telemetry=telemetry)
                    return (out.winner, out.telemetry) if telemetry \
                        else out.winner
                from repro import quant

                widen = quant.margin(x, metric, precision, model=eff_err)
                out = run_halving(problem, rounds, key=k,
                                  survivor_order=order_fn,
                                  telemetry=telemetry, widen=widen)
                winner, verified = quant.exact_winner(problem, out, metric)
                return (winner, verified, out.telemetry) if telemetry \
                    else (winner, verified)

            return jax.vmap(one)(data, valid, keys)
        return jax.jit(impl, donate_argnums=(0,) if eff_donate else ())

    return _memo(("ragged", n_bucket, budget, metric, eff_backend,
                  eff_donate, telemetry, precision, eff_err), build)


# ------------------------------ corpus programs -----------------------------
# Device-resident mutation kernels for the live corpus store
# (:mod:`repro.serve.corpus`). All of them operate on the full power-of-two
# *capacity* bucket — a slot freelist on the host decides which row a
# mutation touches, but the compiled signature depends only on the bucket —
# so an arbitrary insert/delete stream inside one capacity bucket reuses one
# compiled program per mutation kind ("no retrace on mutate", asserted by
# tests/test_serve.py against the "corpus" trace odometer). The centrality
# vector ``cent`` holds the EXACT summed distance of every live slot to all
# live slots (+inf at dead slots); each mutation maintains it with the one
# n-vector of distances the incumbent re-verification needs anyway — the
# same one-vector trick the SWAP phase uses before applying a swap.

def _pairwise_of(backend: str, metric: str):
    from repro.core.backend import get_backend

    return get_backend(backend).pairwise(metric)


def corpus_init_program(*, metric: str = "l2",
                        backend: str = "reference") -> Callable:
    """Jitted centrality bootstrap: ``(buf (cap, d), alive (cap,)) ->
    (cent (cap,), winner)`` — the one O(cap^2) pass that seeds the exact
    centrality vector when a store is built from an existing point set
    (mutations after it are all O(cap))."""
    def build():
        def impl(buf: jnp.ndarray, alive: jnp.ndarray):
            instrument.note_trace("corpus")
            pw = _pairwise_of(backend, metric)
            dmat = pw(buf, buf)                               # (cap, cap)
            sums = jnp.sum(jnp.where(alive[None, :], dmat, 0.0), axis=1)
            cent = jnp.where(alive, sums, jnp.inf)
            return cent, jnp.argmin(cent).astype(jnp.int32)
        return jax.jit(impl)

    return _memo(("corpus_init", metric, backend), build)


def corpus_insert_program(*, metric: str = "l2",
                          backend: str = "reference") -> Callable:
    """Jitted insert: ``(buf, cent, alive, x (d,), slot) -> (buf', cent',
    alive', winner)``. One n-vector of distances prices the new point
    exactly AND updates every live slot's exact centrality (``cent[j] +=
    d(x, j)``); ``winner`` is the exact argmin after the mutation, so the
    caller can tell a kept incumbent from a dethroned one without any
    further device work. The store's buffers are donated (folded away on
    CPU)."""
    eff_donate = donation_enabled()

    def build():
        def impl(buf: jnp.ndarray, cent: jnp.ndarray, alive: jnp.ndarray,
                 x: jnp.ndarray, slot: jnp.ndarray):
            instrument.note_trace("corpus")
            pw = _pairwise_of(backend, metric)
            buf = buf.at[slot].set(x)
            row = pw(x[None, :], buf)[0]                      # (cap,)
            cent_x = jnp.sum(jnp.where(alive, row, 0.0))
            cent = jnp.where(alive, cent + row, jnp.inf).at[slot].set(cent_x)
            alive = alive.at[slot].set(True)
            winner = jnp.argmin(cent).astype(jnp.int32)
            return buf, cent, alive, winner
        return jax.jit(impl,
                       donate_argnums=(0, 1, 2) if eff_donate else ())

    return _memo(("corpus_insert", metric, backend, eff_donate), build)


def corpus_delete_program(*, metric: str = "l2",
                          backend: str = "reference") -> Callable:
    """Jitted delete: ``(buf, cent, alive, slot) -> (cent', alive',
    winner)``. The deleted slot's one n-vector of distances backs its
    contribution out of every surviving centrality; the point data stays in
    the (now dead, freelisted) row and is simply masked everywhere."""
    eff_donate = donation_enabled()

    def build():
        def impl(buf: jnp.ndarray, cent: jnp.ndarray, alive: jnp.ndarray,
                 slot: jnp.ndarray):
            instrument.note_trace("corpus")
            pw = _pairwise_of(backend, metric)
            row = pw(buf[slot][None, :], buf)[0]              # (cap,)
            alive = alive.at[slot].set(False)
            cent = jnp.where(alive, cent - row, jnp.inf)
            winner = jnp.argmin(cent).astype(jnp.int32)
            return cent, alive, winner
        return jax.jit(impl, donate_argnums=(1, 2) if eff_donate else ())

    return _memo(("corpus_delete", metric, backend, eff_donate), build)


def corpus_grow_program() -> Callable:
    """Jitted capacity doubling: ``(buf (cap, d), cent, alive) -> the same
    triple at 2*cap``. The old buffers are donated — freed as soon as the
    copy lands — and the new tail starts dead (+inf centrality, freelisted
    by the host store)."""
    eff_donate = donation_enabled()

    def build():
        def impl(buf: jnp.ndarray, cent: jnp.ndarray, alive: jnp.ndarray):
            instrument.note_trace("corpus")
            cap = buf.shape[0]
            return (jnp.pad(buf, ((0, cap), (0, 0))),
                    jnp.pad(cent, (0, cap), constant_values=jnp.inf),
                    jnp.pad(alive, (0, cap)))
        return jax.jit(impl,
                       donate_argnums=(0, 1, 2) if eff_donate else ())

    return _memo(("corpus_grow", eff_donate), build)


def corpus_gather_program() -> Callable:
    """Jitted snapshot gather: ``(buf (cap, d), idx (n_bucket,)) ->
    (n_bucket, d)`` — packs the live slots (host-ordered, zero-padded index
    vector) into the dense prefix form the ragged engine consumes, so a full
    ``run_halving`` re-run rides the exact same cached
    :func:`ragged_program` as every other ragged tenant."""
    def build():
        def impl(buf: jnp.ndarray, idx: jnp.ndarray):
            instrument.note_trace("corpus")
            return jnp.take(buf, idx, axis=0)
        return jax.jit(impl)

    return _memo(("corpus_gather",), build)


# --------------------------- persistent compile cache ------------------------

def enable_persistent_cache(cache_dir: str) -> str:
    """Point jax's persistent compilation cache at ``cache_dir`` (created if
    missing; thresholds dropped so every engine program is cached). A
    restarted process pays tracing again but skips XLA compilation for every
    program signature it has seen before — the warm-restart path the medoid
    server's warmup route rides. Returns the absolute cache path."""
    path = os.path.abspath(os.path.expanduser(cache_dir))
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    return path
