"""One bandit engine: the estimator-parameterized correlated-SH round loop.

Medoid identification, k-medoids BUILD, and k-medoids SWAP are the same
bandit argmin with different arm-loss estimators — this package is that
sentence as code. :func:`run_halving` is the single round loop (masking,
batching, fused top-k, static schedules); :mod:`repro.engine.estimators`
holds the pluggable scoring protocol; :mod:`repro.engine.schedule` the
paper's deterministic round schedule. The stable user-facing entry points
live one level up in :mod:`repro.api`.
"""
from repro.engine.estimators import (
    ArmEstimator,
    build_delta,
    get_estimator,
    list_estimators,
    medoid_centrality,
    register_estimator,
    swap_delta,
)
from repro.engine.halving import (
    HalvingOutcome,
    HalvingProblem,
    default_order,
    default_select,
    resolve_order_fn,
    resolve_select_fn,
    run_halving,
    sample_refs,
    sample_refs_masked,
)
from repro.engine.schedule import (
    Round,
    Schedule,
    StackedBand,
    StackedSchedule,
    as_schedule,
    round_schedule,
    schedule_pulls,
    stop_round,
)

__all__ = [
    "ArmEstimator", "HalvingOutcome", "HalvingProblem", "Round", "Schedule",
    "StackedBand", "StackedSchedule", "as_schedule",
    "build_delta", "default_order", "default_select", "get_estimator",
    "list_estimators", "medoid_centrality", "register_estimator",
    "resolve_order_fn", "resolve_select_fn",
    "round_schedule", "run_halving", "sample_refs", "sample_refs_masked",
    "schedule_pulls", "stop_round", "swap_delta",
]
