"""THE correlated-SH round loop — one copy, estimator-parameterized.

Before PR 4 the skeleton (draw shared references -> score every surviving
arm -> halve via top-k) existed four times, once per workload: single-query
medoid, masked/ragged medoid, k-medoids BUILD, k-medoids SWAP. BanditPAM
(Tiwari et al., 2020/2023) frames all of these as the *same* bandit argmin
with different arm-loss estimators, and :func:`run_halving` says that in
code: the workload plugs in an :class:`~repro.engine.estimators.ArmEstimator`
and inherits masking, vmapped batching, fused selection, and the
one-XLA-program property for free.

As of PR 6 the loop is **one program by construction**, not by unrolling:
the halving rounds before the output round run as ``lax.scan`` over the
schedule's stacked array form (:meth:`repro.engine.schedule.Schedule.stacked`)
— a fixed-width survivor buffer kept sorted by estimate replaces the
shrinking ``idx``, per-round live counts are positional masks, and
reference draws are fixed-width permutation prefixes weighted by a
positional validity mask. Rounds are grouped into *bands* (default 3 rounds
per scan body) so XLA compiles O(log n / band) round bodies instead of
O(log n), at a bounded fixed-width compute overhead. The **output round**
(``r_stop``) still executes at its exact static legacy shapes outside the
scan, so the outcome's ``theta``/``aux``/winner arithmetic is bit-identical
to the pre-scan loop (scan rounds only make *selection* decisions, which are
invariant to the sub-ulp reduction-order differences fixed-width masking
introduces, except on exact ties already below estimator noise).

Unified semantics, pinned by ``tests/test_engine.py`` against verbatim
snapshots of the four pre-refactor loops (``tests/_legacy_loops.py``):

* **key folding**: one sequential ``key, sub = jax.random.split(key)`` per
  round (inside the scan carry — the same key sequence as the Python loop);
* **reference draws**: uniform without replacement via permutation prefix
  (:func:`sample_refs`); with a ``ref_mask``, the valid-first stable
  partition (:func:`sample_refs_masked`) which degenerates to the unmasked
  draw when every point is valid — the full-bucket bit-exactness theorem;
* **estimates**: the estimator returns raw per-arm *sums*; the engine
  divides by the (static) reference count, or by the drawn *valid* count
  under a ``ref_mask``;
* **arm masking**: ineligible arms (padding, already-chosen medoids) take
  ``+inf`` estimates — they never survive a halving ahead of an eligible arm
  and never win the final argmin;
* **tie-break**: survivor selection and the final argmin resolve ties toward
  the smaller *buffer position* (XLA's stable total-order sort — identical
  to ``jax.lax.top_k`` on negated values, for every ``keep`` at once), for
  every backend including the fused on-chip rank epilogue.

The loop is a pure array program with static shapes only — safe under
``jax.vmap`` (the batched and ragged engines map it over a leading batch
axis) and under ``jax.jit``; :mod:`repro.engine.programs` provides the
cached jitted entry points (with buffer donation) everything dispatches
through.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.engine.schedule import Round, StackedBand, as_schedule
from repro.obs import telemetry as obs_telemetry

if TYPE_CHECKING:   # repro.core is imported lazily (see resolve_order_fn)
    from repro.core.backend import DistanceBackend
    from repro.engine.estimators import ArmEstimator

BackendLike = Union[str, "DistanceBackend", None]
SelectFn = Callable[[jnp.ndarray, int], jnp.ndarray]
OrderFn = Callable[[jnp.ndarray], jnp.ndarray]

# Rounds per scan body (the compile-vs-compute knob; see Schedule.stacked).
DEFAULT_BAND_ROUNDS = 3

# Buffer-width slack factor for margin-widened halving (``widen=``): every
# band (and the output round's survivor set) gets ``min(n, WIDEN_SLACK *
# scheduled_size)`` slots, so a round may retain up to 2x its scheduled
# survivor count before capacity truncation falsifies ``margin_ok``.
WIDEN_SLACK = 2


# ----------------------------- reference draws ------------------------------

def sample_refs(key: jax.Array, n: int, t: int) -> jnp.ndarray:
    """t reference indices, uniform without replacement (permutation prefix)."""
    if t >= n:
        return jnp.arange(n, dtype=jnp.int32)
    return jax.random.permutation(key, n)[:t].astype(jnp.int32)


def sample_refs_masked(key: jax.Array, n: int, t: int,
                       valid: jnp.ndarray) -> jnp.ndarray:
    """t reference indices favoring valid points: a uniform permutation of
    [0, n) stably partitioned so valid indices come first (still in random
    order — sampling without replacement among the valid points), invalid
    ones trail. When every point is valid this is exactly ``sample_refs``
    (the stable partition of an all-zero rank is the identity), which is what
    makes the masked engine bit-identical to the dense one on full buckets.
    """
    if t >= n:
        return jnp.arange(n, dtype=jnp.int32)
    perm = jax.random.permutation(key, n).astype(jnp.int32)
    order = jnp.argsort(jnp.where(valid[perm], 0, 1))  # jnp sort is stable
    return perm[order][:t]


# --------------------------- survivor selection -----------------------------

def default_select(theta: jnp.ndarray, keep: int) -> jnp.ndarray:
    """Survivor selection: indices of the ``keep`` smallest estimates,
    ascending, ties stable toward the smaller index (top_k on negated
    values, static k). Kept for the distributed engines and as the
    ``keep``-parameterized view of :func:`default_order`."""
    return jax.lax.top_k(-theta, keep)[1]


def default_order(theta: jnp.ndarray) -> jnp.ndarray:
    """Full stable ascending ordering of ``theta`` — ``default_select`` for
    every ``keep`` simultaneously (XLA's sort and top_k share the same
    stable float total order, including ``-0.0 < +0.0``). The scan-based
    round loop reorders its fixed-width survivor buffer with this, and the
    next round's positional live mask *is* the halving."""
    return jnp.argsort(theta).astype(jnp.int32)


def resolve_select_fn(backend: BackendLike) -> SelectFn:
    """The static-``keep`` top-k of a backend (fused ``survivor_topk``
    epilogue when registered, XLA top_k otherwise). The scan loop itself
    selects via full orderings (:func:`resolve_order_fn`); this resolver
    remains for API compatibility and the distributed engines."""
    # Imported at call (trace) time: the engine package sits BELOW repro.core
    # in the layering — repro.core.__init__ pulls in corr_sh, which is built
    # on this module, so a module-level import here would be circular.
    from repro.core.backend import get_backend

    fn = get_backend(backend).survivor_topk
    return fn if fn is not None else default_select


def resolve_order_fn(backend: BackendLike) -> OrderFn:
    """The halving step's survivor ordering: a backend with a fused on-chip
    rank epilogue (``survivor_order``, e.g. ``pallas_fused_topk``) keeps it
    on-chip; everyone else gets the default XLA stable sort. Both have
    identical stable-tie semantics, so the choice never changes survivors."""
    from repro.core.backend import get_backend

    fn = get_backend(backend).survivor_order
    return fn if fn is not None else default_order


# ------------------------------- the engine ---------------------------------

@dataclass(frozen=True)
class HalvingProblem:
    """One bandit-argmin instance: the arms, how pulls score, who's eligible.

    ``data``
        ``(n, d)`` arm rows; row i is both arm i and (potential) reference i.
    ``estimator``
        The :class:`ArmEstimator` scoring a reference batch per arm.
    ``arm_mask``
        Optional ``(n,)`` bool — arms eligible to survive / win (``False``
        arms take ``+inf`` estimates). ``None`` = all eligible, and no
        masking ops are traced at all (the dense path stays bit-identical).
    ``ref_mask``
        Optional ``(n,)`` bool — points eligible as references. Draws use the
        valid-first partition, estimator sums are restricted to drawn valid
        references, and estimates divide by the drawn *valid* count. ``None``
        = every point may serve as a reference (static denominator).
    """
    data: jnp.ndarray
    estimator: ArmEstimator
    arm_mask: Optional[jnp.ndarray] = None
    ref_mask: Optional[jnp.ndarray] = None


@dataclass(frozen=True)
class HalvingOutcome:
    """What one ``run_halving`` pass produced.

    ``winner`` is the global arm index (scalar int32); ``winner_pos`` its
    position within ``survivors`` (the final surviving global indices), so
    estimator ``aux`` — whose leading axis tracks survivors — can be indexed
    at the winner (the SWAP estimator reads its ``(C, k)`` delta this way).
    ``theta`` holds the output round's estimates over ``survivors`` and
    ``r_stop`` the (static) index of that round, for pull accounting.
    ``telemetry`` is ``None`` unless the run carried round telemetry — then
    it is the fixed-shape per-round dict of :mod:`repro.obs.telemetry` (one
    row per executed round, scanned rounds + the output round).

    Margin-widened runs (``run_halving(widen=...)``) additionally report
    ``live`` — the traced count of live finalists in the (slack-widened)
    ``survivors`` prefix — and ``margin_ok``, a traced bool that is ``True``
    iff every widened survivor set fit its static buffer all the way down
    (no margin-retained arm was ever capacity-truncated). Plain runs leave
    both ``None``.
    """
    winner: jnp.ndarray
    winner_pos: jnp.ndarray
    survivors: jnp.ndarray
    theta: jnp.ndarray
    aux: Any
    r_stop: int
    telemetry: Any = None
    live: Any = None
    margin_ok: Any = None


def _scan_band(problem: HalvingProblem, band: StackedBand, order_fn: OrderFn,
               key: jax.Array, buf: jnp.ndarray, telemetry: bool = False):
    """Run one band of halving rounds as a single ``lax.scan``.

    ``buf`` is the fixed-width survivor buffer (``band.width`` global arm
    indices, survivors in the sorted prefix). Each scanned round draws a
    full permutation, takes its static ``ref_cap`` prefix as the reference
    buffer, weights references by ``position < t_r`` (times the problem's
    ``ref_mask`` validity, if any), masks arms at ``position >= s_r`` (the
    live prefix) to ``+inf``, and re-sorts the buffer by estimate — the
    next round's tighter live prefix *is* the halving.

    With ``telemetry`` the scan additionally stacks one
    :func:`repro.obs.telemetry.round_stats` row per round (computed on the
    exact masked ``theta`` selection sees) as its ys — pure extra outputs,
    so the carry (and every selection decision) is untouched.
    """
    data, est = problem.data, problem.estimator
    n = data.shape[0]
    width, cap = band.width, band.ref_cap
    xs = (jnp.asarray(band.survivors, jnp.int32),
          jnp.asarray(band.num_refs, jnp.int32))

    def body(carry, sr_tr):
        key, buf = carry
        s_r, t_r = sr_tr
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, n).astype(jnp.int32)
        if problem.ref_mask is not None:
            perm = perm[jnp.argsort(jnp.where(problem.ref_mask[perm], 0, 1))]
        refs = perm[:cap]                                 # static prefix
        pos_ok = jnp.arange(cap, dtype=jnp.int32) < t_r   # this round's t_r
        if problem.ref_mask is not None:
            w = (pos_ok & problem.ref_mask[refs]).astype(jnp.float32)
            denom = jnp.maximum(jnp.sum(w), 1.0)
        else:
            w = pos_ok.astype(jnp.float32)
            denom = t_r.astype(jnp.float32)
        sums, _ = est.score(data[buf], data[refs], refs=refs, ref_mask=w)
        theta = sums / denom                              # (width,)
        alive = jnp.arange(width, dtype=jnp.int32) < s_r
        theta = jnp.where(alive, theta, jnp.inf)
        if problem.arm_mask is not None:
            theta = jnp.where(problem.arm_mask[buf], theta, jnp.inf)
        ys = obs_telemetry.round_stats(theta) if telemetry else None
        buf = buf[order_fn(theta)]        # stable: live ascending, dead last
        return (key, buf), ys

    (key, buf), rows = jax.lax.scan(body, (key, buf), xs)
    return key, buf, rows


def _scan_band_widened(problem: HalvingProblem, band: StackedBand,
                       keeps: Sequence[int], order_fn: OrderFn,
                       key: jax.Array, buf: jnp.ndarray, live: jnp.ndarray,
                       widen: jnp.ndarray, telemetry: bool = False):
    """One band of *margin-widened* halving rounds as a single ``lax.scan``.

    Identical to :func:`_scan_band` (same key sequence, draws, scoring, and
    sort) except the live prefix is a traced carried count instead of the
    scheduled static ``s_r``: after sorting, the round's cut is the
    ``keep_r``-th smallest estimate (``keep_r`` = the scheduled next-round
    survivor count) and every finite arm within ``widen`` of the cut is
    retained — ``live`` becomes ``clip(#inband, keep_r, width)``. Because
    the counted arms always fit the band's (slack-inflated) buffer, no arm
    is ever lost *inside* a band; capacity truncation can only happen at the
    static band-boundary slices, which the caller accounts in ``margin_ok``.
    """
    data, est = problem.data, problem.estimator
    n = data.shape[0]
    width, cap = band.width, band.ref_cap
    xs = (jnp.asarray(band.num_refs, jnp.int32),
          jnp.asarray(tuple(keeps), jnp.int32))

    def body(carry, tr_keep):
        key, buf, live = carry
        t_r, keep_r = tr_keep
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, n).astype(jnp.int32)
        if problem.ref_mask is not None:
            perm = perm[jnp.argsort(jnp.where(problem.ref_mask[perm], 0, 1))]
        refs = perm[:cap]
        pos_ok = jnp.arange(cap, dtype=jnp.int32) < t_r
        if problem.ref_mask is not None:
            w = (pos_ok & problem.ref_mask[refs]).astype(jnp.float32)
            denom = jnp.maximum(jnp.sum(w), 1.0)
        else:
            w = pos_ok.astype(jnp.float32)
            denom = t_r.astype(jnp.float32)
        sums, _ = est.score(data[buf], data[refs], refs=refs, ref_mask=w)
        theta = sums / denom                              # (width,)
        alive = jnp.arange(width, dtype=jnp.int32) < live
        theta = jnp.where(alive, theta, jnp.inf)
        if problem.arm_mask is not None:
            theta = jnp.where(problem.arm_mask[buf], theta, jnp.inf)
        ys = obs_telemetry.round_stats(theta) if telemetry else None
        order = order_fn(theta)
        # The cut: the keep_r-th smallest estimate. An +inf cut (fewer than
        # keep_r finite arms — heavy masking) keeps every finite arm.
        cut = theta[order][keep_r - 1]
        inband = jnp.isfinite(theta) & (theta <= cut + widen)
        live = jnp.clip(jnp.sum(inband.astype(jnp.int32)), keep_r, width)
        buf = buf[order]                  # stable: live ascending, dead last
        return (key, buf, live), ys

    (key, buf, live), rows = jax.lax.scan(body, (key, buf, live), xs)
    return key, buf, live, rows


def _run_halving_widened(problem: HalvingProblem, sched, order_fn: OrderFn,
                         *, key: jax.Array, band_rounds: int,
                         telemetry: bool,
                         widen: jnp.ndarray) -> HalvingOutcome:
    """The ``widen is not None`` body of :func:`run_halving` — see there."""
    data, est = problem.data, problem.estimator
    n = data.shape[0]
    stk = sched.stacked(n, band_rounds=band_rounds, slack=WIDEN_SLACK)
    widen = jnp.asarray(widen, jnp.float32)
    idx = jnp.arange(n, dtype=jnp.int32)
    live = jnp.asarray(n, jnp.int32)
    ok = jnp.asarray(True)
    scanned_rows = []
    for band in stk.bands:
        # Static boundary slice: the ONLY place a margin-retained arm can be
        # dropped. The dropped arms are the worst-ranked of the widened set,
        # but soundness needs all of them — record the overflow.
        ok = ok & (live <= band.width)
        live = jnp.minimum(live, band.width)
        idx = idx[:band.width]
        keeps = tuple(stk.sizes[band.start + i + 1] for i in range(len(band)))
        key, idx, live, rows = _scan_band_widened(
            problem, band, keeps, order_fn, key, idx, live, widen,
            telemetry=telemetry)
        if telemetry:
            scanned_rows.append(rows)

    out_cap = min(n, WIDEN_SLACK * stk.sizes[stk.r_stop])
    ok = ok & (live <= out_cap)
    live = jnp.minimum(live, out_cap)
    survivors = idx[:out_cap]
    rd = sched[stk.r_stop]
    key, sub = jax.random.split(key)
    if problem.ref_mask is not None:
        refs = sample_refs_masked(sub, n, rd.num_refs, problem.ref_mask)
        ref_mask = problem.ref_mask[refs].astype(jnp.float32)    # (t,)
        denom = jnp.maximum(jnp.sum(ref_mask), 1.0)
    else:
        refs = sample_refs(sub, n, rd.num_refs)
        ref_mask = None
        denom = refs.shape[0]              # static Python int
    sums, aux = est.score(data[survivors], data[refs], refs=refs,
                          ref_mask=ref_mask)
    theta = sums / denom
    theta = jnp.where(jnp.arange(out_cap, dtype=jnp.int32) < live,
                      theta, jnp.inf)
    if problem.arm_mask is not None:
        theta = jnp.where(problem.arm_mask[survivors], theta, jnp.inf)
    pos = jnp.argmin(theta)
    tel = None
    if telemetry:
        rows = scanned_rows + [jax.tree_util.tree_map(
            lambda x: x[None], obs_telemetry.round_stats(theta))]
        measured = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs), *rows)
        tel = obs_telemetry.assemble(sched[: stk.r_stop + 1], measured)
    return HalvingOutcome(winner=survivors[pos], winner_pos=pos,
                          survivors=survivors, theta=theta, aux=aux,
                          r_stop=stk.r_stop, telemetry=tel,
                          live=live, margin_ok=ok)


def run_halving(problem: HalvingProblem, schedule: Sequence[Round],
                backend: BackendLike = None, *, key: jax.Array,
                survivor_order: Optional[OrderFn] = None,
                band_rounds: int = DEFAULT_BAND_ROUNDS,
                telemetry: bool = False,
                widen: Optional[jnp.ndarray] = None) -> HalvingOutcome:
    """Run correlated sequential halving over ``schedule`` — the one round
    loop every workload shares, as one scanned array program.

    ``backend`` only resolves the survivor-ordering epilogue (pass
    ``survivor_order`` explicitly to skip the registry lookup, e.g. when
    vmapping many problems over one resolved backend); the distance path
    itself lives inside ``problem.estimator``. ``schedule`` must be
    non-empty (``n == 1`` has an empty schedule — handle it at the call
    site, the answer is arm 0). ``band_rounds`` groups the pre-output rounds
    into scan bodies (see :meth:`repro.engine.schedule.Schedule.stacked`).

    ``telemetry`` additionally carries the fixed-shape per-round telemetry
    buffer of :mod:`repro.obs.telemetry` through the scan (one row per
    executed round) into ``HalvingOutcome.telemetry``. Telemetry is pure
    extra outputs over the same key sequence, draws, and estimates — the
    winner, survivors, ``theta``, and ``aux`` are bitwise identical with it
    on or off (pinned by ``tests/test_obs.py``).

    Estimators must honor the scan-body-safe contract (see
    :mod:`repro.engine.estimators`): pure traced functions of their inputs
    whose ``ref_mask`` weighting is multiplicative, since scanned rounds
    pass positional validity as weights over fixed-width reference buffers.

    ``widen`` (a device scalar, e.g. :func:`repro.quant.error.margin`)
    switches halving to the *margin-widened* rule for perturbed estimators
    (quantized distance paths): each round keeps its scheduled count PLUS
    every finite arm within ``widen`` of the cut, buffers carry
    :data:`WIDEN_SLACK`-fold slack, and the outcome reports the traced
    ``live`` finalist count and a ``margin_ok`` capacity certificate (see
    :class:`HalvingOutcome`). ``widen=None`` (the default) traces the plain
    scheduled-count path, byte-identical to before the option existed — a
    zero-valued ``widen`` is NOT the same thing (the widened rule still
    retains exact ties at the cut and changes buffer shapes).
    """
    sched = as_schedule(schedule)
    if not len(sched):
        raise ValueError("empty schedule: n == 1 needs no halving — the "
                         "caller should short-circuit to arm 0")
    order_fn = survivor_order if survivor_order is not None \
        else resolve_order_fn(backend)
    if widen is not None:
        return _run_halving_widened(problem, sched, order_fn, key=key,
                                    band_rounds=band_rounds,
                                    telemetry=telemetry, widen=widen)
    data, est = problem.data, problem.estimator
    n = data.shape[0]
    stk = sched.stacked(n, band_rounds=band_rounds)
    idx = jnp.arange(n, dtype=jnp.int32)
    scanned_rows = []
    for band in stk.bands:
        idx = idx[:band.width]            # static slice: sorted live prefix
        key, idx, rows = _scan_band(problem, band, order_fn, key, idx,
                                    telemetry=telemetry)
        if telemetry:
            scanned_rows.append(rows)

    # Output round r_stop at its exact static legacy shapes — every value in
    # the outcome (theta, aux, winner arithmetic) is computed here, outside
    # the scan, bit-identically to the pre-scan loop.
    rd = sched[stk.r_stop]
    survivors = idx[:stk.sizes[stk.r_stop]]
    key, sub = jax.random.split(key)
    if problem.ref_mask is not None:
        refs = sample_refs_masked(sub, n, rd.num_refs, problem.ref_mask)
        ref_mask = problem.ref_mask[refs].astype(jnp.float32)    # (t,)
        denom = jnp.maximum(jnp.sum(ref_mask), 1.0)
    else:
        refs = sample_refs(sub, n, rd.num_refs)
        ref_mask = None
        denom = refs.shape[0]              # static Python int
    sums, aux = est.score(data[survivors], data[refs], refs=refs,
                          ref_mask=ref_mask)
    theta = sums / denom
    if problem.arm_mask is not None:
        theta = jnp.where(problem.arm_mask[survivors], theta, jnp.inf)
    pos = jnp.argmin(theta)
    tel = None
    if telemetry:
        rows = scanned_rows + [jax.tree_util.tree_map(
            lambda x: x[None], obs_telemetry.round_stats(theta))]
        measured = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs), *rows)
        tel = obs_telemetry.assemble(sched[: stk.r_stop + 1], measured)
    return HalvingOutcome(winner=survivors[pos], winner_pos=pos,
                          survivors=survivors, theta=theta, aux=aux,
                          r_stop=stk.r_stop, telemetry=tel)
