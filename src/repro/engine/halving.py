"""THE correlated-SH round loop — one copy, estimator-parameterized.

Before PR 4 the skeleton (draw shared references -> score every surviving
arm -> halve via top-k) existed four times, once per workload: single-query
medoid, masked/ragged medoid, k-medoids BUILD, k-medoids SWAP. BanditPAM
(Tiwari et al., 2020/2023) frames all of these as the *same* bandit argmin
with different arm-loss estimators, and :func:`run_halving` says that in
code: the workload plugs in an :class:`~repro.engine.estimators.ArmEstimator`
and inherits masking, vmapped batching, the fused top-k epilogue, and the
static-shape/one-XLA-program property for free.

Unified semantics, pinned by ``tests/test_engine.py`` against verbatim
snapshots of the four pre-refactor loops (``tests/_legacy_loops.py``):

* **key folding**: one sequential ``key, sub = jax.random.split(key)`` per
  round (the audit of the four copies found they all agreed; the distributed
  engines use ``fold_in(key, r)`` instead — a documented, pre-existing
  divergence that is per-engine deterministic and unchanged here);
* **reference draws**: uniform without replacement via permutation prefix
  (:func:`sample_refs`); with a ``ref_mask``, the valid-first stable
  partition (:func:`sample_refs_masked`) which degenerates to the unmasked
  draw when every point is valid — the full-bucket bit-exactness theorem;
* **estimates**: the estimator returns raw per-arm *sums*; the engine
  divides by the (static) reference count, or by the drawn *valid* count
  under a ``ref_mask``;
* **arm masking**: ineligible arms (padding, already-chosen medoids) take
  ``+inf`` estimates — they never survive a halving ahead of an eligible arm
  and never win the final argmin;
* **tie-break**: survivor selection and the final argmin resolve ties toward
  the smaller index (``jax.lax.top_k`` on negated values / ``argmin``), for
  every backend including the fused on-chip top-k.

The loop is a pure array program with static shapes only — safe under
``jax.vmap`` (the batched and ragged engines map it over a leading batch
axis) and under ``jax.jit`` (the Python loop over rounds unrolls; the
early-out branch is static, see :func:`repro.engine.schedule.stop_round`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.engine.schedule import Round

if TYPE_CHECKING:   # repro.core is imported lazily (see resolve_select_fn)
    from repro.core.backend import DistanceBackend
    from repro.engine.estimators import ArmEstimator

BackendLike = Union[str, "DistanceBackend", None]
SelectFn = Callable[[jnp.ndarray, int], jnp.ndarray]


# ----------------------------- reference draws ------------------------------

def sample_refs(key: jax.Array, n: int, t: int) -> jnp.ndarray:
    """t reference indices, uniform without replacement (permutation prefix)."""
    if t >= n:
        return jnp.arange(n, dtype=jnp.int32)
    return jax.random.permutation(key, n)[:t].astype(jnp.int32)


def sample_refs_masked(key: jax.Array, n: int, t: int,
                       valid: jnp.ndarray) -> jnp.ndarray:
    """t reference indices favoring valid points: a uniform permutation of
    [0, n) stably partitioned so valid indices come first (still in random
    order — sampling without replacement among the valid points), invalid
    ones trail. When every point is valid this is exactly ``sample_refs``
    (the stable partition of an all-zero rank is the identity), which is what
    makes the masked engine bit-identical to the dense one on full buckets.
    """
    if t >= n:
        return jnp.arange(n, dtype=jnp.int32)
    perm = jax.random.permutation(key, n).astype(jnp.int32)
    order = jnp.argsort(jnp.where(valid[perm], 0, 1))  # jnp sort is stable
    return perm[order][:t]


# --------------------------- survivor selection -----------------------------

def default_select(theta: jnp.ndarray, keep: int) -> jnp.ndarray:
    """Survivor selection: indices of the ``keep`` smallest estimates,
    ascending, ties stable toward the smaller index (top_k on negated
    values, static k)."""
    return jax.lax.top_k(-theta, keep)[1]


def resolve_select_fn(backend: BackendLike) -> SelectFn:
    """The halving step's top-k: a backend with a fused survivor-selection
    epilogue (``survivor_topk``, e.g. ``pallas_fused_topk``) keeps it
    on-chip; everyone else gets the default XLA top_k. Both have identical
    stable-tie semantics, so the choice never changes survivors."""
    # Imported at call (trace) time: the engine package sits BELOW repro.core
    # in the layering — repro.core.__init__ pulls in corr_sh, which is built
    # on this module, so a module-level import here would be circular.
    from repro.core.backend import get_backend

    fn = get_backend(backend).survivor_topk
    return fn if fn is not None else default_select


# ------------------------------- the engine ---------------------------------

@dataclass(frozen=True)
class HalvingProblem:
    """One bandit-argmin instance: the arms, how pulls score, who's eligible.

    ``data``
        ``(n, d)`` arm rows; row i is both arm i and (potential) reference i.
    ``estimator``
        The :class:`ArmEstimator` scoring a reference batch per arm.
    ``arm_mask``
        Optional ``(n,)`` bool — arms eligible to survive / win (``False``
        arms take ``+inf`` estimates). ``None`` = all eligible, and no
        masking ops are traced at all (the dense path stays bit-identical).
    ``ref_mask``
        Optional ``(n,)`` bool — points eligible as references. Draws use the
        valid-first partition, estimator sums are restricted to drawn valid
        references, and estimates divide by the drawn *valid* count. ``None``
        = every point may serve as a reference (static denominator).
    """
    data: jnp.ndarray
    estimator: ArmEstimator
    arm_mask: Optional[jnp.ndarray] = None
    ref_mask: Optional[jnp.ndarray] = None


@dataclass(frozen=True)
class HalvingOutcome:
    """What one ``run_halving`` pass produced.

    ``winner`` is the global arm index (scalar int32); ``winner_pos`` its
    position within ``survivors`` (the final surviving global indices), so
    estimator ``aux`` — whose leading axis tracks survivors — can be indexed
    at the winner (the SWAP estimator reads its ``(C, k)`` delta this way).
    ``theta`` holds the output round's estimates over ``survivors`` and
    ``r_stop`` the (static) index of that round, for pull accounting.
    """
    winner: jnp.ndarray
    winner_pos: jnp.ndarray
    survivors: jnp.ndarray
    theta: jnp.ndarray
    aux: Any
    r_stop: int


def run_halving(problem: HalvingProblem, schedule: Sequence[Round],
                backend: BackendLike = None, *, key: jax.Array,
                survivor_topk: Optional[SelectFn] = None) -> HalvingOutcome:
    """Run correlated sequential halving over ``schedule`` — the one round
    loop every workload shares.

    ``backend`` only resolves the survivor-selection epilogue (pass
    ``survivor_topk`` explicitly to skip the registry lookup, e.g. when
    vmapping many problems over one resolved backend); the distance path
    itself lives inside ``problem.estimator``. ``schedule`` must be non-empty
    (``n == 1`` has an empty schedule — handle it at the call site, the
    answer is arm 0).
    """
    if not schedule:
        raise ValueError("empty schedule: n == 1 needs no halving — the "
                         "caller should short-circuit to arm 0")
    select = survivor_topk if survivor_topk is not None \
        else resolve_select_fn(backend)
    data, est = problem.data, problem.estimator
    n = data.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)   # surviving arm indices, shrinks
    theta = aux = None
    r_stop = len(schedule) - 1
    for r, rd in enumerate(schedule):
        key, sub = jax.random.split(key)
        if problem.ref_mask is not None:
            refs = sample_refs_masked(sub, n, rd.num_refs, problem.ref_mask)
            ref_mask = problem.ref_mask[refs].astype(jnp.float32)   # (t_r,)
            denom = jnp.maximum(jnp.sum(ref_mask), 1.0)
        else:
            refs = sample_refs(sub, n, rd.num_refs)
            ref_mask = None
            denom = refs.shape[0]          # static Python int
        sums, aux = est.score(data[idx], data[refs], refs=refs,
                              ref_mask=ref_mask)                    # (s_r,)
        theta = sums / denom
        if problem.arm_mask is not None:
            theta = jnp.where(problem.arm_mask[idx], theta, jnp.inf)
        if rd.exact or idx.shape[0] <= 2:
            r_stop = r
            break
        keep = math.ceil(idx.shape[0] / 2)
        idx = idx[select(theta, keep)]     # smallest-theta half survives
    pos = jnp.argmin(theta)
    return HalvingOutcome(winner=idx[pos], winner_pos=pos, survivors=idx,
                          theta=theta, aux=aux, r_stop=r_stop)
