"""Mutation-stream driver: exercise the live-corpus serving stack end to end.

``python -m repro.serve.stream`` feeds a seeded insert/delete stream through
a :class:`~repro.serve.maintain.MaintainedMedoid`, answering a query after
every mutation, and emits the same observability artifacts as the serving
CLIs — a Prometheus text exposition (``--metrics-out``) and a JSONL trace
(``--trace``) that ``python -m repro.obs.validate`` accepts. CI's serve-smoke
step runs exactly this.

``--verify`` re-derives every answer from scratch: after each mutation the
live snapshot is re-bootstrapped into a fresh
:class:`~repro.serve.corpus.CorpusStore` (one exact O(n^2) pass) and the
served slot must equal the exact medoid of that corpus version (exact ties
and float32 accumulation residue excepted — see :func:`check_answer`).
That is the acceptance property of the incremental maintenance layer; it
holds whenever the re-run budget is in the exact regime, so with
``--verify`` and no explicit ``--budget-per-arm`` the driver picks
``B * ceil(log2 B)`` for the largest reachable bucket ``B`` automatically.
"""
from __future__ import annotations

import argparse
import json
import math

import numpy as np

from repro.core.backend import list_backends
from repro.core.bucketing import DEFAULT_MIN_BUCKET, bucket_n
from repro.obs import MetricsRegistry, TraceSession, instrument_exposition
from repro.serve.corpus import CorpusStore
from repro.serve.maintain import MaintainedMedoid

# Pull-count buckets for the per-mutation cost histogram: spans one
# capacity-bucket n-vector (O(n)) through full re-runs (O(n log n)).
MUTATION_PULL_BUCKETS = (16.0, 64.0, 256.0, 1024.0, 4096.0, 16384.0,
                         65536.0, 262144.0)


class StreamMetrics:
    """Instrument bundle of the mutation-stream driver (same registry /
    exposition machinery as :class:`~repro.obs.metrics.ServerMetrics`)."""

    def __init__(self):
        self.registry = MetricsRegistry()
        r = self.registry
        self.mutations = r.counter(
            "corpus_mutations_total", "corpus mutations applied", ("kind",))
        self.settles = r.counter(
            "corpus_settle_total",
            "how each mutation re-established the medoid", ("reason",))
        self.pulls = r.counter(
            "corpus_pulls_total",
            "distance evaluations spent maintaining the medoid", ("phase",))
        self.mutation_cost = r.histogram(
            "corpus_mutation_pulls",
            "distance evaluations charged to one mutation",
            buckets=MUTATION_PULL_BUCKETS)

    def record(self, kind: str, update) -> None:
        self.mutations.labels(kind).inc()
        self.settles.labels(update.reason).inc()
        self.mutation_cost.observe(update.pulls)

    def finalize(self, mm: MaintainedMedoid) -> None:
        s = mm.stats()
        self.pulls.labels("init").inc(s["init_pulls"])
        self.pulls.labels("incremental").inc(s["incremental_pulls"])
        self.pulls.labels("rerun").inc(s["rerun_pulls"])

    def exposition(self) -> str:
        return self.registry.exposition() + instrument_exposition()


def exact_state(store: CorpusStore):
    """From-scratch reference for ``store``'s current version: re-bootstrap
    the live snapshot (one O(n^2) pass through the same
    :func:`~repro.engine.programs.corpus_init_program` every store uses)
    and return ``(exact medoid slot, centralities in live-slot order)``."""
    fresh = CorpusStore.from_points(store.snapshot(), metric=store.metric,
                                    backend=store.backend,
                                    min_bucket=store.min_bucket)
    cent = np.asarray(fresh.cent)[fresh.live_slots()]
    return int(store.live_slots()[int(cent.argmin())]), cent


def check_answer(store: CorpusStore, slot: int) -> bool:
    """Whether served ``slot`` matches the from-scratch recompute of this
    corpus version: the same slot on generic-position data, or (under
    ties / float32 accumulation residue — see the precision caveat in
    :mod:`repro.serve.corpus`) a slot whose true centrality is within
    fractional tolerance of the true minimum."""
    want, cent = exact_state(store)
    if slot == want:
        return True
    pos = int(np.searchsorted(store.live_slots(), slot))
    lo = float(cent.min())
    return float(cent[pos]) <= lo + 1e-3 * max(1.0, abs(lo))


def run_stream(mm: MaintainedMedoid, *, steps: int, seed: int = 0,
               insert_frac: float = 0.7, verify: bool = False,
               metrics: StreamMetrics | None = None,
               trace: TraceSession | None = None) -> dict:
    """Apply ``steps`` seeded mutations, querying after each; returns the
    final stats dict (plus ``verified`` when ``verify`` is set). Raises
    ``AssertionError`` on the first served answer that is not the exact
    medoid of its corpus version."""
    rng = np.random.default_rng(seed)
    store = mm.store
    verified = 0
    for step in range(steps):
        do_insert = store.n == 0 or rng.random() < insert_frac
        if do_insert:
            upd = mm.insert(rng.normal(size=store.d).astype(np.float32))
            kind = "insert"
        else:
            upd = mm.delete(int(rng.choice(store.live_slots())))
            kind = "delete"
        slot, version = mm.query()
        if metrics is not None:
            metrics.record(kind, upd)
        if trace is not None:
            trace.event("mutation", kind=kind, version=version,
                        reason=upd.reason, reran=upd.reran, n=store.n)
            trace.event("select", winner=slot, pulls=int(upd.pulls),
                        n=store.n, version=version)
        if verify and store.n:
            assert check_answer(store, slot), (
                f"step {step} (version {version}): served slot {slot} is "
                f"not the exact medoid of this corpus version")
            verified += 1
    if metrics is not None:
        metrics.finalize(mm)
    out = mm.stats()
    if verify:
        out["verified"] = verified
    return out


def exact_budget_per_arm(max_n: int, min_bucket: int) -> int:
    """The per-arm budget putting every reachable bucket in the exact
    regime (``B * ceil(log2 B)`` at the largest bucket ``B``)."""
    b = bucket_n(max(2, max_n), min_bucket)
    return b * max(1, math.ceil(math.log2(b)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--n0", type=int, default=24,
                    help="initial corpus size (seeded bootstrap)")
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--metric", default="l2",
                    choices=["l1", "l2", "sql2", "cosine"])
    ap.add_argument("--backend", default="reference",
                    choices=list(list_backends()))
    ap.add_argument("--insert-frac", type=float, default=0.7,
                    help="probability a mutation is an insert")
    ap.add_argument("--budget-per-arm", type=int, default=None,
                    help="re-run budget per arm (default: 24, or the exact "
                         "regime when --verify is set)")
    ap.add_argument("--min-bucket", type=int, default=DEFAULT_MIN_BUCKET)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="assert every served answer equals the exact "
                         "medoid of its corpus version (from scratch)")
    ap.add_argument("--trace", default=None, metavar="PATH", dest="trace_out",
                    help="stream mutation/select events to this JSONL file")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition here on exit")
    args = ap.parse_args(argv)

    budget = args.budget_per_arm
    if budget is None:
        budget = exact_budget_per_arm(args.n0 + args.steps,
                                      args.min_bucket) if args.verify else 24

    rng = np.random.default_rng(args.seed + 1)
    store = CorpusStore.from_points(
        rng.normal(size=(args.n0, args.d)).astype(np.float32),
        metric=args.metric, backend=args.backend, min_bucket=args.min_bucket)
    mm = MaintainedMedoid(store, budget_per_arm=budget, seed=args.seed)

    session = TraceSession(args.trace_out, meta={
        "workload": "serve_stream", "backend": args.backend,
        "metric": args.metric}) if args.trace_out else None
    metrics = StreamMetrics()
    out = run_stream(mm, steps=args.steps, seed=args.seed,
                     insert_frac=args.insert_frac, verify=args.verify,
                     metrics=metrics, trace=session)
    if session is not None:
        session.close()
    if args.metrics_out:
        with open(args.metrics_out, "w") as fh:
            fh.write(metrics.exposition())
    out["budget_per_arm"] = budget
    print(json.dumps(out))


if __name__ == "__main__":
    main()
