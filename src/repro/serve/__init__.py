"""Live corpus serving: mutable corpora, incremental medoid maintenance,
deadline-aware multi-tenant scheduling.

The layers below this package answer *frozen* corpora: a query ships its
own candidate set, the engine halves it, the answer never outlives the
call. Production embedding services don't work that way — the corpus is a
long-lived, mutating object and "the medoid" is a maintained quantity, not
a one-shot answer. This package grows the serving stack accordingly:

* :mod:`repro.serve.corpus` — :class:`CorpusStore`, a versioned
  device-resident point store (slot freelist inside power-of-two capacity
  buckets; every mutation is one cached XLA program from
  :mod:`repro.engine.programs`, never a retrace);
* :mod:`repro.serve.maintain` — :class:`MaintainedMedoid`, incremental
  medoid maintenance over a store: a mutation re-verifies the incumbent
  with a single exact n-vector (the SWAP trick) and falls back to a full
  ``run_halving`` re-run only when the incumbent is actually dethroned;
* :mod:`repro.serve.scheduler` — per-request priorities + deadlines,
  earliest-deadline-first admission with load shedding fed by the
  :class:`~repro.obs.metrics.ServerMetrics` latency histograms (the policy
  behind ``MedoidServer(policy="edf")``);
* :mod:`repro.serve.stream` — the mutation-stream driver CLI
  (``python -m repro.serve.stream``) CI's serve-smoke job runs.
"""
from __future__ import annotations

from repro.serve.corpus import CorpusStore
from repro.serve.maintain import MaintainedMedoid, MedoidUpdate
from repro.serve.scheduler import (POLICIES, EdfPolicy, FifoPolicy,
                                   LatencyModel, resolve_policy)

__all__ = [
    "CorpusStore", "EdfPolicy", "FifoPolicy", "LatencyModel",
    "MaintainedMedoid", "MedoidUpdate", "POLICIES", "resolve_policy",
]
