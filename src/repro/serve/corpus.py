"""Versioned, device-resident mutable corpus store.

A :class:`CorpusStore` owns three device buffers sized to a power-of-two
*capacity* bucket (:func:`repro.core.bucketing.bucket_n`):

* ``buf (cap, d)`` — the point rows (dead rows hold stale data, masked
  everywhere);
* ``cent (cap,)`` — the EXACT summed distance of each live slot to every
  live slot (+inf at dead slots), maintained incrementally;
* ``alive (cap,)`` — the live mask.

The host side keeps a slot **freelist** and a mirror of the live mask, so a
mutation never needs a device round-trip to find its row. Because every
mutation kernel (:func:`repro.engine.programs.corpus_insert_program` /
``corpus_delete_program``) operates on the full capacity bucket, the
compiled signature depends only on ``(cap, d, metric, backend)`` — an
arbitrary insert/delete stream inside one capacity bucket reuses exactly
one compiled program per mutation kind ("no retrace on mutate"; the
``"corpus"`` odometer of :mod:`repro.engine.instrument` pins it). When the
freelist runs dry the capacity bucket **doubles** and the old buffers are
donated to the growth program.

Each mutation costs one n-vector of distances (O(cap) pulls, counted in
:attr:`CorpusStore.mutation_pulls`) and updates the exact centrality of
every live point — which is precisely the information the incremental
medoid maintenance layer (:mod:`repro.serve.maintain`) needs to re-verify
its incumbent without re-running the bandit. ``version`` bumps on every
mutation; answers are always attributable to one exact corpus version.

Precision caveat: centralities accumulate in float32 (add a row on insert,
subtract it on delete), so after many mutations a slot's stored centrality
can differ from a fresh summation by float-cancellation residue (~1e-3
relative in long streams). On generic-position data the winner is
unaffected; under EXACT ties or near-ties inside that residue, the argmin
may resolve differently than a from-scratch recompute — the served point
is always an eps-exact medoid, not necessarily the same index.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.backend import get_backend
from repro.core.bucketing import DEFAULT_MIN_BUCKET, bucket_n
from repro.core.distances import METRICS
from repro.engine import instrument, programs


@dataclasses.dataclass(frozen=True)
class CorpusStats:
    """One snapshot of a store's accounting."""
    n: int                      # live points
    capacity: int               # power-of-two slot bucket
    version: int                # mutations applied so far
    inserts: int
    deletes: int
    grows: int                  # capacity doublings
    mutation_pulls: int         # distance evals spent on mutations
    init_pulls: int             # one-time bootstrap distance evals


class CorpusStore:
    """A mutable, versioned point store with exact incremental centralities.

    ``insert`` returns a stable integer **slot id** — the handle every
    answer speaks in (a snapshot index would shift under mutation). Slots
    are recycled through the freelist (lowest-numbered free slot first, so
    replayed streams hit identical slot sequences).
    """

    def __init__(self, d: int, *, metric: str = "l2",
                 backend: str = "reference",
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 capacity: Optional[int] = None,
                 precision: str = "fp32"):
        if d < 1:
            raise ValueError(f"need d >= 1, got {d}")
        if metric not in METRICS:
            raise ValueError(f"unknown metric {metric!r}; one of {METRICS}")
        if precision != "fp32":
            # Quantized store: every distance path — the bootstrap pass, the
            # per-mutation n-vectors, and maintenance re-runs (which use
            # store.backend) — rides the quantized backend for this
            # precision. The incremental centralities are then *quantized*-
            # exact: the float32-cancellation caveat above applies on top of
            # the quantization perturbation, so ties within the quantization
            # error may resolve differently than the fp32 store's.
            from repro import quant
            backend = quant.backend_for(precision, base=backend)
        get_backend(backend)            # fail at construction
        self.d = int(d)
        self.metric = metric
        self.backend = backend
        self.precision = precision
        self.min_bucket = int(min_bucket)
        cap = bucket_n(max(1, int(capacity or min_bucket)), self.min_bucket)
        self.buf = jnp.zeros((cap, self.d), jnp.float32)
        self.cent = jnp.full((cap,), jnp.inf, jnp.float32)
        self.alive = jnp.zeros((cap,), bool)
        self._alive_host = np.zeros((cap,), bool)
        self._free: list[int] = list(range(cap - 1, -1, -1))  # pop() -> 0
        self._winner = None             # device scalar: argmin(cent)
        self.version = 0
        self.inserts = self.deletes = self.grows = 0
        self.mutation_pulls = 0         # distance evals spent on mutations
        self.init_pulls = 0             # one-time bootstrap cost

    # ------------------------------ construction ---------------------------
    @classmethod
    def from_points(cls, data, **kwargs) -> "CorpusStore":
        """Build a store holding ``data (n, d)`` in slots ``0..n-1``. Seeds
        the exact centrality vector with ONE O(n^2) bootstrap pass (the
        only quadratic moment a store ever pays — every mutation after it
        is O(n))."""
        data = jnp.asarray(data, jnp.float32)
        if data.ndim != 2:
            raise ValueError(f"expected (n, d) data, got shape {data.shape}")
        n = int(data.shape[0])
        store = cls(int(data.shape[1]),
                    capacity=max(n, kwargs.pop("capacity", 0) or 0), **kwargs)
        if n:
            cap = store.capacity
            store.buf = store.buf.at[:n].set(data)
            store.alive = store.alive.at[:n].set(True)
            store._alive_host[:n] = True
            store._free = list(range(cap - 1, n - 1, -1))
            fn = programs.corpus_init_program(metric=store.metric,
                                              backend=store.backend)
            instrument.note_dispatch("corpus")
            store.cent, store._winner = fn(store.buf, store.alive)
            store.init_pulls = cap * cap
        return store

    # -------------------------------- queries ------------------------------
    @property
    def capacity(self) -> int:
        return int(self.buf.shape[0])

    @property
    def n(self) -> int:
        return int(self._alive_host.sum())

    def is_live(self, slot: int) -> bool:
        return 0 <= slot < self.capacity and bool(self._alive_host[slot])

    @property
    def exact_medoid_slot(self) -> Optional[int]:
        """Slot id of the exact medoid of the current version (one scalar
        device read; None for an empty store)."""
        if self.n == 0 or self._winner is None:
            return None
        return int(self._winner)

    def live_slots(self) -> np.ndarray:
        """Live slot ids, ascending — the store's canonical snapshot order
        (a from-scratch recompute on ``snapshot()`` speaks in positions of
        this array)."""
        return np.flatnonzero(self._alive_host)

    def snapshot(self) -> np.ndarray:
        """Host copy of the live points in slot order — the reference
        corpus a from-scratch recompute of this version runs on."""
        return np.asarray(self.buf)[self._alive_host]

    def gather(self, n_bucket: int) -> jnp.ndarray:
        """Pack the live rows into a dense ``(n_bucket, d)`` prefix (zero
        index padding past ``n``) via the cached gather program — the form
        the ragged engine consumes for a full re-run."""
        order = self.live_slots()
        if n_bucket < order.size:
            raise ValueError(f"n_bucket={n_bucket} < live count {order.size}")
        idx = np.zeros((n_bucket,), np.int32)
        idx[: order.size] = order
        instrument.note_dispatch("corpus")
        return programs.corpus_gather_program()(self.buf, jnp.asarray(idx))

    # ------------------------------- mutations ------------------------------
    def insert(self, x) -> int:
        """Insert one ``(d,)`` point; returns its slot id. Doubles the
        capacity bucket first if the freelist is dry. Updates every live
        centrality with the new point's distance row (one n-vector)."""
        x = jnp.asarray(x, jnp.float32)
        if x.shape != (self.d,):
            raise ValueError(f"expected a ({self.d},) point, got {x.shape}")
        if not self._free:
            self._grow()
        slot = self._free.pop()
        fn = programs.corpus_insert_program(metric=self.metric,
                                            backend=self.backend)
        instrument.note_dispatch("corpus")
        self.buf, self.cent, self.alive, self._winner = fn(
            self.buf, self.cent, self.alive, x, jnp.int32(slot))
        self._alive_host[slot] = True
        self.mutation_pulls += self.capacity
        self.inserts += 1
        self.version += 1
        return slot

    def delete(self, slot: int) -> None:
        """Delete a live slot (its id returns to the freelist). Backs the
        point's distance row out of every surviving centrality."""
        slot = int(slot)
        if not self.is_live(slot):
            raise ValueError(f"slot {slot} is not live")
        fn = programs.corpus_delete_program(metric=self.metric,
                                            backend=self.backend)
        instrument.note_dispatch("corpus")
        self.cent, self.alive, self._winner = fn(
            self.buf, self.cent, self.alive, jnp.int32(slot))
        self._alive_host[slot] = False
        self._free.append(slot)
        self.mutation_pulls += self.capacity
        self.deletes += 1
        self.version += 1

    def _grow(self) -> None:
        cap = self.capacity
        instrument.note_dispatch("corpus")
        self.buf, self.cent, self.alive = programs.corpus_grow_program()(
            self.buf, self.cent, self.alive)
        self._alive_host = np.concatenate(
            [self._alive_host, np.zeros((cap,), bool)])
        # new slots go UNDER existing free ids: lowest slot still pops first
        self._free = list(range(2 * cap - 1, cap - 1, -1)) + self._free
        self.grows += 1

    # -------------------------------- stats --------------------------------
    def stats(self) -> CorpusStats:
        return CorpusStats(n=self.n, capacity=self.capacity,
                           version=self.version, inserts=self.inserts,
                           deletes=self.deletes, grows=self.grows,
                           mutation_pulls=self.mutation_pulls,
                           init_pulls=self.init_pulls)
