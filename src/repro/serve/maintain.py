"""Incremental medoid maintenance over a mutable corpus.

BanditPAM's SWAP phase never trusts a bandit winner blindly: before a swap
is applied it is re-verified with ONE exact n-vector of distances
(:func:`repro.cluster.kmedoids._exact_swap_delta`). The same trick turns a
corpus mutation into an O(n) *incumbent re-verification* instead of a full
bandit re-run: the :class:`~repro.serve.corpus.CorpusStore` mutation
kernels already price the mutated point against the whole corpus (that one
n-vector) while updating the exact centrality of every live slot — so
after a mutation, whether the incumbent medoid survived is a single scalar
comparison, not a computation.

:class:`MaintainedMedoid` runs that protocol:

* mutation keeps the incumbent (the exact argmin didn't move) -> serve the
  incumbent unchanged, total cost one n-vector — O(n) pulls, counted in
  :attr:`incremental_pulls`;
* a challenger beats the incumbent, or the deleted point WAS the medoid ->
  fall back to ONE full ``run_halving`` re-run on the current corpus
  version, dispatched through the same cached
  :func:`~repro.engine.programs.ragged_program` as every other ragged
  tenant (the re-run key is ``fold_in(key(seed), version)``, so a
  from-scratch ``find_medoids_ragged`` on this version's snapshot with the
  same seed is **bit-identical** — pinned by ``tests/test_serve.py``).

With budgets in the exact regime (``budget_per_arm >= n_bucket *
ceil(log2 n_bucket)`` — the regime the generous-budget serving tests
already use), every served answer equals the exact medoid of the current
corpus version on generic-position data; under exact ties or near-ties
within float32 accumulation residue the served point is an eps-exact
medoid (see the precision caveat in :mod:`repro.serve.corpus`). The
store's centralities make the incumbent check itself budget-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bucketing import bucket_n
from repro.core.corr_sh import ragged_medoids
from repro.engine import round_schedule, stop_round
from repro.serve.corpus import CorpusStore


@dataclasses.dataclass(frozen=True)
class MedoidUpdate:
    """What one mutation did to the maintained answer."""
    version: int               # corpus version after the mutation
    medoid_slot: Optional[int]  # served incumbent (None: empty corpus)
    reran: bool                # True: full bandit re-run; False: O(n) keep
    pulls: int                 # distance evals charged to this mutation
    reason: str                # kept | challenger | deleted_incumbent |
    #                            bootstrap | emptied


class MaintainedMedoid:
    """The maintained medoid of a live :class:`CorpusStore`.

    ``query()`` is free — the incumbent slot id is host state. Mutations go
    through :meth:`insert` / :meth:`delete`, which mutate the store and
    re-establish the incumbent per the protocol above. All pull accounting
    is exact and split incremental-vs-re-run, so benchmarks can report the
    maintenance ratio directly.
    """

    def __init__(self, store: Optional[CorpusStore] = None, *,
                 d: Optional[int] = None, metric: str = "l2",
                 backend: str = "reference", budget_per_arm: int = 24,
                 min_bucket: Optional[int] = None, seed: int = 0):
        if store is None:
            if d is None:
                raise ValueError("pass a CorpusStore or d= to build one")
            store = CorpusStore(d, metric=metric, backend=backend,
                                **({} if min_bucket is None
                                   else {"min_bucket": min_bucket}))
        self.store = store
        self.budget_per_arm = int(budget_per_arm)
        self._key = jax.random.key(seed)
        self.medoid_slot: Optional[int] = None
        self.reruns = 0
        self.kept = 0
        self.queries = 0
        self.incremental_pulls = 0     # n-vector re-verification cost
        self.rerun_pulls = 0           # scheduled pulls of full re-runs
        if store.n:
            # adopting a pre-populated store: the incumbent must come from
            # the same protocol a mutation-triggered re-run uses
            self._rerun()

    # ------------------------------- queries -------------------------------
    def query(self) -> tuple[Optional[int], int]:
        """Serve the maintained answer: ``(medoid slot id, corpus version)``.
        No device work — the incumbent is re-established at mutation time."""
        self.queries += 1
        return self.medoid_slot, self.store.version

    @property
    def pulls(self) -> int:
        """Total distance evaluations (bootstrap + mutations + re-runs)."""
        return (self.store.init_pulls + self.incremental_pulls
                + self.rerun_pulls)

    # ------------------------------ mutations ------------------------------
    def insert(self, x) -> MedoidUpdate:
        """Insert one point; re-verify (and only if dethroned, re-run)."""
        self.store.insert(x)
        return self._settle(deleted_incumbent=False)

    def delete(self, slot: int) -> MedoidUpdate:
        """Delete a live slot; a deleted incumbent always forces a re-run."""
        was_incumbent = slot == self.medoid_slot
        self.store.delete(slot)
        return self._settle(deleted_incumbent=was_incumbent)

    def _settle(self, *, deleted_incumbent: bool) -> MedoidUpdate:
        store = self.store
        pulls = store.capacity          # the mutation's exact n-vector
        self.incremental_pulls += pulls
        if store.n == 0:
            self.medoid_slot = None
            return MedoidUpdate(store.version, None, False, pulls, "emptied")
        if deleted_incumbent:
            reason = "deleted_incumbent"
        elif self.medoid_slot is None:
            reason = "bootstrap"
        elif store.exact_medoid_slot != self.medoid_slot:
            # a challenger's exact centrality beat the incumbent's — the
            # one case the single n-vector cannot settle in the bandit's
            # favor
            reason = "challenger"
        else:
            self.kept += 1
            return MedoidUpdate(store.version, self.medoid_slot, False,
                                pulls, "kept")
        rerun_pulls = self._rerun()
        return MedoidUpdate(store.version, self.medoid_slot, True,
                            pulls + rerun_pulls, reason)

    def _rerun(self) -> int:
        """Full correlated-SH re-run on the current corpus version (the
        same cached ragged program every other tenant dispatches; key =
        ``fold_in(key(seed), version)`` so the answer is reproducible from
        the version alone). Returns its scheduled pull cost."""
        store = self.store
        n = store.n
        order = store.live_slots()
        n_bucket = bucket_n(n, store.min_bucket)
        budget = self.budget_per_arm * n_bucket
        snap = store.gather(n_bucket)
        key = jax.random.fold_in(self._key, store.version)
        meds = ragged_medoids(snap[None], jnp.asarray([n], jnp.int32), key,
                              budget=budget, metric=store.metric,
                              backend=store.backend,
                              min_bucket=store.min_bucket, donate=True)
        self.medoid_slot = int(order[int(meds[0])])
        rounds = round_schedule(n_bucket, budget)
        pulls = sum(r.pulls for r in rounds[: stop_round(rounds) + 1]) \
            if rounds else 0
        self.rerun_pulls += pulls
        self.reruns += 1
        return pulls

    # -------------------------------- stats --------------------------------
    def stats(self) -> dict:
        s = self.store.stats()
        mutations = s.inserts + s.deletes
        return {
            "n": s.n, "capacity": s.capacity, "version": s.version,
            "mutations": mutations, "kept": self.kept,
            "reruns": self.reruns, "queries": self.queries,
            "grows": s.grows,
            "incremental_pulls": self.incremental_pulls,
            "rerun_pulls": self.rerun_pulls,
            "init_pulls": s.init_pulls,
            "total_pulls": self.pulls,
            "medoid_slot": self.medoid_slot,
            "kept_frac": round(self.kept / mutations, 4) if mutations else 0.0,
        }
