"""Multi-tenant scheduling policies for the continuous-batching server.

The :class:`~repro.launch.serve_medoid.MedoidServer` originally serviced
its queue in pure FIFO order — fine for one tenant, wrong the moment
requests carry different urgency. This module supplies the scheduling layer
behind the server's ``policy=`` flag:

* :class:`FifoPolicy` — the original behavior, bit-for-bit: the oldest
  request's bucket group dispatches first (the default, so existing
  callers see no change);
* :class:`EdfPolicy` — earliest-deadline-first admission with load
  shedding: the queue is ordered by ``(deadline, -priority, arrival)``,
  the most urgent request's shape bucket dispatches next, and requests
  that *cannot* make their deadline anymore are shed at scheduling time
  instead of wasting a dispatch. "Cannot" is estimated from the live
  :class:`~repro.obs.metrics.ServerMetrics` dispatch-latency histograms
  through a :class:`LatencyModel` — a bucket that has already compiled is
  priced at its steady-state quantile, an unseen bucket at the worst
  observed compile-phase quantile (the compile-vs-steady split PR 7's
  metrics exist to expose). No observations yet means no shedding: the
  model never invents a latency.

A policy is a pure queue transformer: ``select(queue, now=..., ...)``
returns ``(batch, rest, shed)`` and never touches the device — the server
owns dispatching, accounting, and metrics.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence

# Estimate callback the server hands the policy: request -> seconds one
# dispatch of its bucket is expected to take (None: no data, never shed).
EstimateFn = Callable[[object], Optional[float]]


class LatencyModel:
    """Deadline-feasibility estimates from the server's latency histograms.

    Reads the ``medoid_dispatch_seconds`` family of a
    :class:`~repro.obs.metrics.ServerMetrics` bundle. For a bucket the
    server has already compiled, the estimate is the steady-phase
    ``quantile`` (falling back to that bucket's compile-phase data before
    any steady dispatch landed). For an unseen bucket the honest estimate
    is a *compile*: the worst compile-phase quantile observed across all
    buckets. Returns ``None`` when there is no applicable observation —
    the caller must treat that as "cannot estimate", not "free".
    """

    def __init__(self, metrics, *, quantile: float = 0.9):
        if not 0.0 < quantile <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {quantile}")
        self.metrics = metrics
        self.quantile = quantile

    def estimate(self, bucket: str, *, compiled: bool) -> Optional[float]:
        fam = self.metrics.latency
        if compiled:
            for phase in ("steady", "compile"):
                child = fam.children.get((bucket, phase))
                if child is not None and child.count:
                    return child.quantile(self.quantile)
            return None
        worst = None
        for (_, phase), child in fam.children.items():
            if phase == "compile" and child.count:
                q = child.quantile(self.quantile)
                worst = q if worst is None else max(worst, q)
        return worst


class FifoPolicy:
    """The pre-policy scheduler, verbatim: service the oldest request's
    bucket group, up to ``max_batch`` of its bucket-mates, in arrival
    order. Deadlines and priorities are carried but ignored."""

    name = "fifo"

    def select(self, queue: Sequence, *, now: float, max_batch: int,
               bucket_key: Callable, estimate: EstimateFn):
        if not queue:
            return [], [], []
        bkey = bucket_key(queue[0])
        batch, rest = [], []
        for q in queue:
            if len(batch) < max_batch and bucket_key(q) == bkey:
                batch.append(q)
            else:
                rest.append(q)
        return batch, rest, []


class EdfPolicy:
    """Earliest-deadline-first with load shedding.

    Ordering: ``(deadline, -priority, arrival)`` — an absent deadline
    sorts last (best-effort traffic), priority breaks ties among equal
    deadlines, arrival order breaks everything else (so two undated
    equal-priority requests still serve FIFO). The most urgent request
    picks the bucket; its bucket-mates fill the batch in the same urgency
    order.

    Shedding (``shed_hopeless=True``): a request whose deadline already
    passed, or whose deadline precedes ``now + estimate(request)``, is
    removed from the queue unanswered — a dispatch it cannot use is a
    dispatch some other tenant loses. Requests the model cannot price
    (``estimate`` returns None) are never shed.
    """

    name = "edf"

    def __init__(self, *, shed_hopeless: bool = True):
        self.shed_hopeless = shed_hopeless

    @staticmethod
    def _urgency(req, seq: int):
        deadline = req.deadline_s if req.deadline_s is not None else math.inf
        return (deadline, -getattr(req, "priority", 0), seq)

    def select(self, queue: Sequence, *, now: float, max_batch: int,
               bucket_key: Callable, estimate: EstimateFn):
        shed, viable = [], []
        for q in queue:
            if self.shed_hopeless and q.deadline_s is not None:
                if q.deadline_s <= now:
                    shed.append(q)
                    continue
                est = estimate(q)
                if est is not None and now + est > q.deadline_s:
                    shed.append(q)
                    continue
            viable.append(q)
        if not viable:
            return [], [], shed
        order = sorted(range(len(viable)),
                       key=lambda i: self._urgency(viable[i], i))
        bkey = bucket_key(viable[order[0]])
        batch = [viable[i] for i in order
                 if bucket_key(viable[i]) == bkey][:max_batch]
        chosen = {q.rid for q in batch}
        rest = [q for q in viable if q.rid not in chosen]
        return batch, rest, shed


POLICIES = {"fifo": FifoPolicy, "edf": EdfPolicy}


def resolve_policy(policy):
    """``"fifo"`` / ``"edf"`` / a policy instance -> a policy instance."""
    if isinstance(policy, str):
        try:
            return POLICIES[policy]()
        except KeyError:
            raise ValueError(f"unknown policy {policy!r}; one of "
                             f"{sorted(POLICIES)}") from None
    if not hasattr(policy, "select"):
        raise TypeError(f"policy must define select(), got {type(policy)!r}")
    return policy
