"""Fleet-scale runtime hardening: watchdog, straggler stats, restart loop.

What runs for real on this CPU container:
  * `StepWatchdog` — per-step wall-time tracker with a robust (median + MAD)
    straggler threshold; `check()` flags steps that exceed it and drives the
    mitigation callback (on a fleet: pre-empt + re-dispatch to a hot spare;
    here: recorded + surfaced in metrics).
  * `run_with_restarts` — supervises a training function, restarting it from
    the latest committed checkpoint on failure, up to `max_restarts`. Combined
    with the stateless data pipeline (skip-to-step) and atomic checkpoints
    this gives exactly-once-equivalent training semantics.
  * corrSH rounds are idempotent given (key, round) — a re-executed round
    recomputes the same reference set and survivor set, so the medoid engine
    restarts mid-algorithm from the per-round survivor checkpoint with no
    statistical drift.

Elastic scaling: `elastic_remesh` rebuilds a mesh from the currently healthy
device count (largest (dp, tp) grid with tp preserved if possible) and
reshards a checkpoint onto it via checkpoint.manager.restore(shardings=...).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional

import jax


@dataclasses.dataclass
class StepWatchdog:
    window: int = 50
    mad_factor: float = 5.0
    min_samples: int = 8
    _times: list = dataclasses.field(default_factory=list)
    stragglers: int = 0

    def record(self, seconds: float) -> bool:
        """Record a step time; returns True if it's a straggler step."""
        ts = self._times
        is_straggler = False
        if len(ts) >= self.min_samples:
            srt = sorted(ts)
            med = srt[len(srt) // 2]
            mad = sorted(abs(t - med) for t in ts)[len(ts) // 2]
            if seconds > med + self.mad_factor * max(mad, 0.05 * med):
                is_straggler = True
                self.stragglers += 1
        ts.append(seconds)
        if len(ts) > self.window:
            ts.pop(0)
        return is_straggler


def run_with_restarts(step_fn: Callable[[int], int], *, start_step: int,
                      total_steps: int, max_restarts: int = 3,
                      on_restart: Optional[Callable[[int, Exception], int]] = None
                      ) -> int:
    """Drive `step_fn(step) -> next_step` to completion with restart-on-crash.
    `on_restart(step, exc) -> resume_step` reloads state (checkpoint) and
    returns where to resume."""
    step = start_step
    restarts = 0
    while step < total_steps:
        try:
            step = step_fn(step)
        except Exception as exc:  # noqa: BLE001 — supervisor boundary
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is None:
                raise
            step = on_restart(step, exc)
    return step


def elastic_mesh_shape(num_devices: int, preferred_tp: int = 16
                       ) -> tuple[int, int]:
    """Largest (dp, tp) grid for the currently healthy device count: keep tp
    if it divides, else the largest power-of-two tp that does."""
    tp = preferred_tp
    while tp > 1 and num_devices % tp:
        tp //= 2
    return num_devices // tp, tp


def elastic_remesh(axis_names=("data", "model"), preferred_tp: int = 16):
    n = len(jax.devices())
    dp, tp = elastic_mesh_shape(n, preferred_tp)
    return jax.make_mesh((dp, tp), axis_names)
