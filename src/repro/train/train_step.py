"""train_step / serve_step factories.

``make_train_step(model)`` returns a pure (state, batch) -> (state, metrics)
function with optional gradient accumulation (scan over microbatches) and
optional int8 gradient compression with error feedback. The launcher jits it
with in/out shardings from ``repro.launch.partition``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import adamw, compress, schedule


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    num_microbatches: int = 1
    remat: bool = True
    grad_compression: bool = False


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    ef: Optional[compress.EFState]   # error feedback (grad compression)
    step: jnp.ndarray


def init_train_state(model: Model, key, tcfg: TrainCfg) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt=adamw.init(params),
        ef=compress.init_error_feedback(params) if tcfg.grad_compression else None,
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(model: Model, tcfg: TrainCfg):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=tcfg.remat)
        return loss, metrics

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if tcfg.num_microbatches > 1:
            n = tcfg.num_microbatches
            sliced = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

            def micro(acc, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), m

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)
            (grads, loss_sum), metrics = jax.lax.scan(
                micro, (zero, jnp.zeros((), jnp.float32)), sliced)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss_sum / n
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch)

        ef = state.ef
        if tcfg.grad_compression:
            grads, ef = compress.apply_error_feedback(grads, ef)

        lr = schedule.cosine_with_warmup(
            state.step + 1, peak_lr=tcfg.peak_lr,
            warmup_steps=tcfg.warmup_steps, total_steps=tcfg.total_steps)
        params, opt, opt_metrics = adamw.update(
            grads, state.opt, state.params, lr=lr,
            weight_decay=tcfg.weight_decay, max_grad_norm=tcfg.max_grad_norm)
        new_state = TrainState(params=params, opt=opt, ef=ef,
                               step=state.step + 1)
        return new_state, {"loss": loss, "lr": lr, **metrics, **opt_metrics}

    return train_step


def make_serve_steps(model: Model, max_len: int):
    """(prefill_fn, decode_fn) for the serving path."""

    def prefill(params, batch):
        return model.prefill(params, batch, max_len)

    def decode(params, token, cache, pos, batch=None):
        return model.decode_step(params, token, cache, pos, batch=batch)

    return prefill, decode
