"""Reduced-precision distance backends: bf16 and AQT-style symmetric int8.

Production embedding corpora are stored and served in bf16/int8; the fp32
Gram path the engine runs by default leaves the MXU's low-precision rate on
the table. This module registers quantized :class:`~repro.core.backend.
DistanceBackend` implementations of the same two round primitives every
backend provides (``pairwise`` / ``centrality_sums``, plus the
``fused_estimators`` hook for ``medoid_centrality``), so every workload —
single/batch/ragged medoid, k-medoids BUILD and SWAP, corpus mutation
kernels — can run quantized through the existing registry without touching
a single call site:

``quant_bf16``
    Inputs are rounded to bfloat16 *at the Gram stage only*; products
    accumulate in fp32 (``preferred_element_type``), row norms and metric
    epilogues (sqrt / normalization / clamps) stay fp32. On TPU the bf16
    ``dot_general`` runs the MXU at its doubled bf16 rate. ℓ1 has no matmul
    form; it sees storage rounding only (bf16-cast inputs, fp32 sums).

``quant_int8``
    AQT-style symmetric per-row quantization (the MaxText idiom): each row
    is scaled by ``s_i = max|x_i| / 127``, rounded to int8, and the Gram
    block accumulates **exactly** in int32 before one fp32 dequantization
    ``G = (Q_x Q_y^T) * s_x s_y^T``. The only error is the per-element
    rounding ``|x - s q| <= s/2``; the int8 x int8 -> int32 matmul path is
    the MXU's highest-rate mode.

``quant_bf16_fused``
    ``quant_bf16``'s centrality routed through the Pallas ``dot_centrality``
    kernel at ``compute_dtype=bfloat16`` (the in-kernel cast added for this
    subsystem) — the memory-roofline-optimal quantized path on TPU; ℓ1
    rides the VPU kernel on bf16-rounded inputs.

Quantized estimates are *perturbed* estimates: the engine widens the
survivor margin by the error model of :mod:`repro.quant.error` and verifies
the final survivor set in exact fp32 (:mod:`repro.quant.verify`) — see
``MedoidConfig(precision=...)``. Using a quantized backend directly via
``backend="quant_bf16"`` runs plain (unwidened) halving on quantized
estimates, which is what BUILD/SWAP/corpus mutation consume.

All functions here are pure traced jnp/Pallas code — scan-body-safe per the
estimator contract (no host syncs), and deterministic: the same inputs
quantize to the same ints on every call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import distances
from repro.core.backend import DistanceBackend, register_backend
from repro.kernels import ops as kops

#: Facade-level precision names (``MedoidConfig.precision``).
PRECISIONS = ("fp32", "bf16", "int8")

#: precision -> registered quantized backend name (fp32 -> None: no override).
_QUANT_BACKEND = {"fp32": None, "bf16": "quant_bf16", "int8": "quant_int8"}


def check_precision(precision: str) -> str:
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"one of {PRECISIONS}")
    return precision


def backend_for(precision: str, base: str = "reference"):
    """The quantized backend name a precision maps to (None for fp32).

    ``base`` is the caller's fp32 backend choice: a fused Pallas base keeps
    a fused quantized path where one exists (bf16 — the in-kernel cast),
    everything else gets the jnp quantized backend for that precision.
    """
    name = _QUANT_BACKEND[check_precision(precision)]
    if name == "quant_bf16" and base in ("pallas_fused", "pallas_fused_topk"):
        return "quant_bf16_fused"
    return name


# ----------------------------- bf16 Gram path -------------------------------

def _bf16(a: jnp.ndarray) -> jnp.ndarray:
    """Storage rounding: fp32 -> bf16 (the quantization step, nothing else)."""
    return a.astype(jnp.float32).astype(jnp.bfloat16)


def gram_bf16(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """bf16-multiply / fp32-accumulate Gram block: the MXU's bf16 mode."""
    return jax.lax.dot_general(
        _bf16(x), _bf16(y),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


# ----------------------------- int8 AQT path --------------------------------

def quantize_rows_int8(x: jnp.ndarray):
    """Symmetric per-row int8 quantization: ``(q (n, d) int8, s (n,) f32)``
    with ``x ~= q * s[:, None]`` and ``|x - q s| <= s / 2`` per element."""
    xf = x.astype(jnp.float32)
    s = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    s = jnp.maximum(s, jnp.finfo(jnp.float32).tiny)  # all-zero rows: q = 0
    q = jnp.clip(jnp.round(xf / s[..., None]), -127.0, 127.0).astype(jnp.int8)
    return q, s


def gram_int8(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Per-row-scaled int8 Gram: exact int32 accumulation, one fp32
    dequantization — quantization error is pure input rounding."""
    qx, sx = quantize_rows_int8(x)
    qy, sy = quantize_rows_int8(y)
    g = jax.lax.dot_general(
        qx, qy,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return g.astype(jnp.float32) * sx[:, None] * sy[None, :]


def dequantize_rows_int8(x: jnp.ndarray) -> jnp.ndarray:
    """The int8 representation mapped back to fp32 (what the ℓ1 path and the
    error model's probe actually measure distances between)."""
    q, s = quantize_rows_int8(x)
    return q.astype(jnp.float32) * s[..., None]


# ------------------------- metric blocks per precision ----------------------

def _norms_sq(a: jnp.ndarray) -> jnp.ndarray:
    af = a.astype(jnp.float32)
    return jnp.sum(af * af, axis=-1)


def _unit_rows(a: jnp.ndarray) -> jnp.ndarray:
    af = a.astype(jnp.float32)
    return af / jnp.maximum(jnp.linalg.norm(af, axis=-1, keepdims=True),
                            1e-12)


def _quant_pairwise(metric: str, gram, l1_repr):
    """Pairwise block for ``metric`` with a quantized Gram stage. Row norms
    and the metric epilogue stay fp32, so the only perturbation relative to
    the reference block is the Gram error (ℓ1: the representation error)."""
    if metric == "l1":
        def l1(x, y):
            xq, yq = l1_repr(x), l1_repr(y)
            return jnp.sum(jnp.abs(xq[:, None, :] - yq[None, :, :]), axis=-1)
        return l1
    if metric == "cosine":
        def cos(x, y):
            return 1.0 - gram(_unit_rows(x), _unit_rows(y))
        return cos
    if metric in ("l2", "sql2"):
        def sq(x, y):
            g = gram(x, y)
            v = jnp.maximum(_norms_sq(x)[:, None] + _norms_sq(y)[None, :]
                            - 2.0 * g, 0.0)
            return jnp.sqrt(v) if metric == "l2" else v
        return sq
    raise ValueError(f"unknown metric {metric!r}; one of {distances.METRICS}")


def _bf16_repr(a: jnp.ndarray) -> jnp.ndarray:
    return _bf16(a).astype(jnp.float32)


def quant_pairwise(metric: str, precision: str):
    """The quantized pairwise block for ``(metric, precision)`` — also what
    the error model's probe compares against the reference block."""
    check_precision(precision)
    if precision == "fp32":
        return distances.pairwise(metric)
    if precision == "bf16":
        return _quant_pairwise(metric, gram_bf16, _bf16_repr)
    return _quant_pairwise(metric, gram_int8, dequantize_rows_int8)


def _centrality_of(pairwise_fn):
    def fn(x, y, ref_mask=None):
        return distances.masked_rowsum(pairwise_fn(x, y), ref_mask)
    return fn


def _make_backend(name: str, precision: str, description: str):
    def pairwise(metric: str):
        return quant_pairwise(metric, precision)

    def centrality(metric: str):
        return _centrality_of(quant_pairwise(metric, precision))

    return DistanceBackend(
        name=name,
        pairwise=pairwise,
        centrality_sums=centrality,
        materializes_block=True,
        description=description,
        fused_estimators={"medoid_centrality": centrality},
    )


register_backend(_make_backend(
    "quant_bf16", "bf16",
    "bf16-multiply / fp32-accumulate Gram (quantized storage rounding)"))

register_backend(_make_backend(
    "quant_int8", "int8",
    "AQT-style symmetric per-row int8 Gram, exact int32 accumulation"))


# --------------------- fused (Pallas) bf16 centrality -----------------------

def _fused_bf16_centrality(metric: str):
    if metric == "l1":
        kern = kops.centrality_kernel(metric)

        def l1(x, y, ref_mask=None):
            return kern(_bf16_repr(x), _bf16_repr(y), ref_mask=ref_mask)
        return l1
    return functools.partial(kops.kernel_centrality_sums, metric=metric,
                             compute_dtype="bfloat16")


_BF16_FUSED = {"medoid_centrality": _fused_bf16_centrality}

register_backend(DistanceBackend(
    name="quant_bf16_fused",
    pairwise=lambda metric: quant_pairwise(metric, "bf16"),
    centrality_sums=_fused_bf16_centrality,
    materializes_block=False,
    description="bf16 Gram centrality fused in the Pallas dot_centrality "
                "kernel (in-kernel cast, fp32 accumulation)",
    fused_estimators=_BF16_FUSED,
))
