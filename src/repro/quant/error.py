"""Quantization-error model: how far can a quantized estimate drift?

The engine's halving decisions compare per-arm centrality estimates
``theta_i = mean_j d(x_i, x_j)`` over a *shared* reference draw. Quantizing
the distance path perturbs every distance by at most some ``eps_d``
(data-dependent), hence every estimate — a mean of distances — by at most
the same ``eps_d``, identically for fp32 and quantized runs of the *same*
draw. Widening the survivor cut by ``2 * eps_d`` therefore makes halving
sound against quantization noise: any arm the fp32 scoring of the same
round would keep has ``theta_f(i) <= cut_f``, so its quantized estimate
satisfies ``theta_q(i) <= theta_f(i) + eps <= cut_f + eps <= cut_q +
2*eps`` (the quantized cut can sit at most ``eps`` below the fp32 cut over
the same alive set) — quantization alone can never evict it. That is the
margin :func:`repro.engine.run_halving` applies when ``widen=`` is set, and
why the exact fp32 epilogue (:mod:`repro.quant.verify`) then certifies the
returned arm.

Two error models, both pure traced device code (scan-body / vmap safe):

``analytic``
    Deterministic worst-case bounds from dtype resolution and data norms
    (max row ℓ2/ℓ1/∞ norms). Certified but conservative by roughly
    ``sqrt(d)`` versus typical rounding behavior — near-tie-dense data can
    overflow the widened buffer's capacity and trigger the fp32 fallback.

``probe`` (default)
    Measured: the quantized and reference distance blocks are compared on a
    small strided probe of the data's own rows, and the margin is the
    observed maximum error times a safety factor. Realistic margins at a
    high-probability (not adversarial) guarantee; the exact fp32
    verification epilogue still holds unconditionally for the finalists.

Per-metric analytic bounds (``M2/M1/Minf`` = max row ℓ2/ℓ1/∞ norm):

* bf16 (unit roundoff ``u = 2^-8``; per-product relative bound ``EPS_BF16 =
  2^-7`` covers both input roundings + fp32 accumulation slack):
  ``|Δgram| <= EPS * M2^2`` (Cauchy–Schwarz), so sql2 ``<= 2 EPS M2^2``,
  l2 ``<= sqrt(2 EPS) M2`` (via ``|sqrt(a) - sqrt(b)| <= sqrt(|a - b|)``),
  cosine ``<= EPS`` (rows fp32-normalized first), l1 ``<= 2 u M1``.
* int8 (per-row scale ``s_i = max|x_i| / 127 <= S = Minf / 127``; int32
  accumulation is exact): ``|Δgram| <= S * M1 + d * S^2 / 4``, sql2/l2/
  cosine as above (cosine stats taken on the unit rows), l1 ``<= d * S``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import distances
from repro.quant.backends import check_precision, quant_pairwise

#: Error models understood by :func:`margin`.
ERROR_MODELS = ("probe", "analytic")

#: Per-product relative bound for the bf16-multiply/fp32-accumulate Gram
#: (two input roundings at unit roundoff 2^-8, doubled for fp32-accumulation
#: slack and second-order terms).
EPS_BF16 = 2.0 ** -7
#: bf16 unit roundoff (per-element storage rounding, the ℓ1 path's scale).
U_BF16 = 2.0 ** -8
#: Probe safety factor: measured max error on the probe block times this.
DEFAULT_SAFETY = 4.0
#: Probe rows (strided over the data; the probe block is probe x probe).
DEFAULT_PROBE = 64


def _unit_rows(a: jnp.ndarray) -> jnp.ndarray:
    af = a.astype(jnp.float32)
    return af / jnp.maximum(jnp.linalg.norm(af, axis=-1, keepdims=True),
                            1e-12)


def _row_stats(data: jnp.ndarray):
    """(max row ℓ2, max row ℓ1, max |entry|) — device scalars."""
    af = jnp.abs(data.astype(jnp.float32))
    m2 = jnp.sqrt(jnp.max(jnp.sum(af * af, axis=-1)))
    m1 = jnp.max(jnp.sum(af, axis=-1))
    minf = jnp.max(af)
    return m2, m1, minf


def _gram_bound(data: jnp.ndarray, precision: str) -> jnp.ndarray:
    m2, m1, minf = _row_stats(data)
    if precision == "bf16":
        return EPS_BF16 * m2 * m2
    d = data.shape[-1]
    s = minf / 127.0
    return s * m1 + d * s * s / 4.0


def analytic_distance_bound(data: jnp.ndarray, metric: str,
                            precision: str) -> jnp.ndarray:
    """Certified worst-case ``max_pair |d_q - d_f|`` over rows of ``data``
    (a device scalar; pure traced code)."""
    check_precision(precision)
    if precision == "fp32":
        return jnp.zeros((), jnp.float32)
    if metric == "cosine":
        return 2.0 * _gram_bound(_unit_rows(data), precision)
    if metric == "l1":
        if precision == "bf16":
            _, m1, _ = _row_stats(data)
            return 2.0 * U_BF16 * m1
        _, _, minf = _row_stats(data)
        return data.shape[-1] * (minf / 127.0)
    eg = _gram_bound(data, precision)
    if metric == "sql2":
        return 2.0 * eg
    if metric == "l2":
        return jnp.sqrt(2.0 * eg)
    raise ValueError(f"unknown metric {metric!r}; "
                     f"one of {distances.METRICS}")


def probe_distance_bound(data: jnp.ndarray, metric: str, precision: str,
                         probe: int = DEFAULT_PROBE) -> jnp.ndarray:
    """Measured ``max |d_q - d_f|`` over a ``p x p`` block of ``p = min(n,
    probe)`` evenly-strided rows (deterministic — no key), as a device
    scalar. O(p^2 d) work, a small constant fraction of any real schedule's
    pull budget.

    The statistic is the max over probe arms of the *mean* absolute error
    over probe references — the per-arm centrality perturbation the halving
    estimates actually see (every estimate is a mean over a shared
    reference draw, so signed per-distance errors largely cancel; the
    per-distance max is ~an order of magnitude larger and realized by no
    estimate). The self-pair diagonal is excluded: ``d(x_i, x_i) = 0`` and
    the l2 sqrt turns an O(eps) Gram error into an O(sqrt(eps)) distance
    error there, yet a self-pair contributes at most ``1/t_r`` of any
    round's mean.
    """
    check_precision(precision)
    if precision == "fp32":
        return jnp.zeros((), jnp.float32)
    n = int(data.shape[0])
    p = min(n, int(probe))
    idx = jnp.linspace(0.0, float(n - 1), p).round().astype(jnp.int32)
    rows = data[idx]
    dq = quant_pairwise(metric, precision)(rows, rows)
    df = distances.pairwise(metric)(rows, rows)
    err = jnp.abs(dq - df)
    err = jnp.where(jnp.eye(p, dtype=bool), 0.0, err)
    return jnp.max(jnp.sum(err, axis=1) / jnp.maximum(p - 1, 1))


def margin(data: jnp.ndarray, metric: str, precision: str, *,
           model: str = "probe", safety: float = DEFAULT_SAFETY,
           probe: int = DEFAULT_PROBE) -> jnp.ndarray:
    """The survivor-cut widening ``2 * eps_d`` for a quantized run (device
    scalar; feeds ``run_halving(widen=...)``). ``model="analytic"`` uses the
    certified bound; ``model="probe"`` (default) the measured probe error
    times ``safety``."""
    if model not in ERROR_MODELS:
        raise ValueError(f"unknown error model {model!r}; "
                         f"one of {ERROR_MODELS}")
    if model == "analytic":
        return 2.0 * analytic_distance_bound(data, metric, precision)
    return 2.0 * safety * probe_distance_bound(data, metric, precision,
                                               probe=probe)
