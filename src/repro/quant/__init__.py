"""Quantized distance subsystem: bf16/int8 backends, error model, verifier.

Importing this package registers the quantized backends (``quant_bf16``,
``quant_int8``, ``quant_bf16_fused``) with the distance-backend registry;
:func:`repro.core.backend.get_backend` and ``list_backends`` import it
lazily, so the names resolve everywhere without explicit imports. See the
README's "Precision" section and the module docs of
:mod:`repro.quant.backends` / :mod:`repro.quant.error` /
:mod:`repro.quant.verify`.
"""
from repro.quant.backends import (
    PRECISIONS,
    backend_for,
    check_precision,
    dequantize_rows_int8,
    gram_bf16,
    gram_int8,
    quant_pairwise,
    quantize_rows_int8,
)
from repro.quant.error import (
    DEFAULT_PROBE,
    DEFAULT_SAFETY,
    EPS_BF16,
    ERROR_MODELS,
    U_BF16,
    analytic_distance_bound,
    margin,
    probe_distance_bound,
)
from repro.quant.verify import exact_winner, verify_pulls, verify_width

__all__ = [
    "DEFAULT_PROBE", "DEFAULT_SAFETY", "EPS_BF16", "ERROR_MODELS",
    "PRECISIONS", "U_BF16", "analytic_distance_bound", "backend_for",
    "check_precision", "dequantize_rows_int8", "exact_winner", "gram_bf16",
    "gram_int8", "margin", "probe_distance_bound", "quant_pairwise",
    "quantize_rows_int8", "verify_pulls", "verify_width",
]
