"""Exact fp32 verification epilogue for quantized halving runs.

A quantized run ends with a widened survivor buffer: up to ``2 * s_stop``
finalists (the margin-retained arms), a traced live count, and a
``margin_ok`` flag saying whether every margin-widened survivor set fit its
buffer all the way down (see ``run_halving(widen=...)``). This module
spends one exact fp32 n-vector per finalist — the same one-vector trick the
SWAP phase and the corpus mutation kernels use — to score every live
finalist against the FULL reference set in the reference backend, and
returns the exact-centrality argmin. The returned arm is therefore exactly
the fp32 medoid *of the finalist set*, unconditionally; when ``margin_ok``
held, the margins guarantee quantization never evicted an arm a same-draw
fp32 round would have kept, which is the ``verified`` certificate the
facade reports.

Cost: ``verify_width(n, rounds) * n`` distance evaluations — a vanishing
fraction of the schedule at production n (the finalist buffer is O(1)-ish),
accounted in ``MedoidResult.pulls``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import distances
from repro.engine.halving import WIDEN_SLACK, HalvingOutcome, HalvingProblem
from repro.engine.schedule import as_schedule


def verify_width(n: int, rounds) -> int:
    """Static width of the widened output-round survivor buffer (the number
    of finalists the epilogue scores) — ``min(n, WIDEN_SLACK * s_stop)``,
    derived from the same stacked schedule the engine runs."""
    stk = as_schedule(rounds).stacked(n)
    return min(int(n), WIDEN_SLACK * stk.sizes[stk.r_stop])


def verify_pulls(n: int, rounds) -> int:
    """Distance evaluations the epilogue spends: one n-vector per finalist."""
    return verify_width(n, rounds) * int(n)


def exact_winner(problem: HalvingProblem, out: HalvingOutcome,
                 metric: str):
    """Exact fp32 winner among the live finalists of a widened outcome.

    Returns ``(winner, verified)``: the global index of the finalist with
    the smallest exact fp32 centrality over all (valid) references, and the
    run's ``margin_ok`` flag. Pure traced code — safe under vmap (the
    batched/ragged quantized programs map it per query).
    """
    data = problem.data
    surv = out.survivors
    ref_mask = None
    if problem.ref_mask is not None:
        ref_mask = problem.ref_mask.astype(jnp.float32)
    sums = distances.centrality_sums(data[surv], data, metric,
                                     ref_mask=ref_mask)
    alive = jnp.arange(surv.shape[0], dtype=jnp.int32) < out.live
    theta = jnp.where(alive, sums, jnp.inf)
    if problem.arm_mask is not None:
        theta = jnp.where(problem.arm_mask[surv], theta, jnp.inf)
    pos = jnp.argmin(theta)
    return surv[pos], out.margin_ok
