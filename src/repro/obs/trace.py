"""Structured trace events: JSONL spans + optional ``jax.profiler`` hooks.

A :class:`TraceSession` turns the engine's device-resident telemetry buffers
(:mod:`repro.obs.telemetry`) and the trace/dispatch odometers
(:mod:`repro.engine.instrument`) into an append-only JSONL event stream a
human (or the CI validator, :mod:`repro.obs.validate`) can read back:

    {"event": "session", "seq": 0, "ts": ..., "version": 1, ...}
    {"event": "span", "name": "dispatch", "dur_s": ..., "traces": {...}, ...}
    {"event": "round", "r": 0, "survivors": 512, "num_refs": 23, ...}
    {"event": "select", "winner": 318, "pulls": 15402, ...}

Every record carries ``event`` (its type), a monotone ``seq``, and a wall
``ts``. Spans (``span(name)``) wrap host-side phases — trace, compile,
dispatch, select — and record their duration plus the *deltas* of the engine
odometers while the span was open (so ``traces > 0`` inside a dispatch span
is exactly "this dispatch compiled something"). Round events are emitted
from a telemetry dict by :meth:`TraceSession.record_rounds`; their per-round
``pulls`` sum to the scheduled totals the facade reports, which the
validator checks against the enclosing ``select`` event.

Profiler integration (both off by default):

* ``annotate=True`` wraps every span in a ``jax.profiler.TraceAnnotation``
  of the same name, so bandit phases line up with XLA events in a
  TensorBoard / Perfetto profile;
* ``profiler_dir=...`` brackets the whole session in
  ``jax.profiler.start_trace`` / ``stop_trace`` (written on ``close()``).
"""
from __future__ import annotations

import contextlib
import json
import math
import time
from typing import IO, Optional

from repro.engine import instrument

SCHEMA_VERSION = 1


def _jsonable(v):
    """Coerce numpy / jax scalars and non-finite floats to JSON-safe values
    (NaN/Inf become null — JSON has no spelling for them)."""
    if hasattr(v, "item") and not isinstance(v, (str, bytes)):
        try:
            v = v.item()
        except (TypeError, ValueError):
            v = str(v)
    if isinstance(v, float) and not math.isfinite(v):
        return None
    return v


class TraceSession:
    """One JSONL trace stream (events also kept in memory for programmatic
    consumers). Usable as a context manager; ``close()`` is idempotent."""

    def __init__(self, path: Optional[str] = None, *, annotate: bool = False,
                 profiler_dir: Optional[str] = None, meta: Optional[dict] = None):
        self._fh: Optional[IO[str]] = open(path, "w") if path else None
        self.path = path
        self.annotate = annotate
        self.profiler_dir = profiler_dir
        self.events: list[dict] = []
        self._seq = 0
        self._closed = False
        self._profiling = False
        if profiler_dir:
            import jax

            jax.profiler.start_trace(profiler_dir)
            self._profiling = True
        self.event("session", version=SCHEMA_VERSION, **(meta or {}))

    # ------------------------------- emission -------------------------------
    def event(self, event: str, **fields) -> dict:
        """Append one record to the stream (and the in-memory list)."""
        if self._closed:
            raise RuntimeError("TraceSession is closed")
        rec = {"event": event, "seq": self._seq, "ts": round(time.time(), 6)}
        rec.update({k: _jsonable(v) for k, v in fields.items()})
        self._seq += 1
        self.events.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()
        return rec

    @contextlib.contextmanager
    def span(self, name: str, **fields):
        """Wrap a host-side phase: emits one ``span`` record on exit with
        ``dur_s`` and the engine odometer deltas observed while open (plus a
        ``jax.profiler.TraceAnnotation`` when ``annotate`` is set)."""
        ann = contextlib.nullcontext()
        if self.annotate:
            import jax

            ann = jax.profiler.TraceAnnotation(name)
        t0 = time.perf_counter()
        with instrument.deltas() as d, ann:
            yield
        self.event("span", name=name, dur_s=round(time.perf_counter() - t0, 6),
                   traces=d.counters()["traces"],
                   dispatches=d.counters()["dispatches"], **fields)

    def record_rounds(self, telemetry: dict, *, slot: Optional[int] = None,
                      **fields) -> None:
        """Emit one ``round`` event per telemetry row. ``telemetry`` is the
        host-side dict from :func:`repro.obs.telemetry_to_host` (leaves
        ``(R,)``, or ``(B, R)`` from the batched/ragged engines — pass
        ``slot`` to pick one query's rows; batched rows share their schedule
        columns, so slot 0 is representative for pull accounting)."""
        tel = telemetry
        if slot is not None:
            tel = {k: v[slot] for k, v in telemetry.items()}
        rows = len(next(iter(tel.values()))) if tel else 0
        for r in range(rows):
            self.event("round", r=r,
                       **{k: tel[k][r] for k in tel}, **fields)

    def record_result(self, result, **fields) -> None:
        """Emit a :class:`repro.api.MedoidResult`: its per-round telemetry
        (when the query ran with ``telemetry=True``) followed by the
        ``select`` record whose ``pulls`` the round rows sum to."""
        if getattr(result, "telemetry", None) is not None:
            self.record_rounds(result.telemetry)
        self.event("select", winner=result.medoid, pulls=result.pulls,
                   n=result.n, algo=result.algo, metric=result.metric,
                   backend=result.backend, **fields)

    # ------------------------------- lifecycle ------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self.event("session_end", events=self._seq)
        self._closed = True
        if self._profiling:
            import jax

            jax.profiler.stop_trace()
            self._profiling = False
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "TraceSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
