"""Validate observability artifacts against the documented schema.

CI's metrics smoke job (and ``tests/test_obs.py``) run the launch CLIs with
``--trace`` / ``--metrics-out`` and feed the outputs through this module:

    PYTHONPATH=src python -m repro.obs.validate trace.jsonl metrics.txt

Checks, per artifact:

* **JSONL trace** — every line parses; every record has ``event`` (str),
  monotone ``seq`` (int), ``ts`` (number); the stream opens with a
  ``session`` record (matching :data:`repro.obs.trace.SCHEMA_VERSION`) and
  ends with ``session_end``; ``round`` records carry the full telemetry
  schema (:data:`repro.obs.telemetry.FIELDS`); every ``select`` record's
  ``pulls`` equals the summed ``pulls`` of the ``round`` records since the
  previous ``select`` — the pull-reconciliation acceptance check;
* **metrics exposition** — non-empty; every line is a ``# HELP`` / ``# TYPE``
  comment or a ``name{labels} value`` sample; every sample's family has a
  preceding TYPE line; histogram ``_count`` equals its ``+Inf`` bucket.

Both validators raise ``ValueError`` with a line-numbered message on the
first violation and return a summary dict on success.
"""
from __future__ import annotations

import json
import re
import sys

from repro.obs.telemetry import FIELDS as ROUND_FIELDS
from repro.obs.trace import SCHEMA_VERSION

_SAMPLE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?P<labels>\{[^{}]*\})?\s+(?P<value>[^\s]+)$')
_COMMENT = re.compile(r"^# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]*( .*)?$")


def validate_trace(path: str) -> dict:
    """Validate one JSONL trace file; returns ``{"events": N, "rounds": R,
    "selects": S}``."""
    events = by_type = None
    with open(path) as fh:
        lines = fh.read().splitlines()
    if not lines:
        raise ValueError(f"{path}: empty trace")
    events, by_type = [], {}
    for i, line in enumerate(lines, 1):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}:{i}: not JSON ({e})") from None
        if not isinstance(rec, dict):
            raise ValueError(f"{path}:{i}: record is not an object")
        for field, types in (("event", str), ("seq", int),
                             ("ts", (int, float))):
            if not isinstance(rec.get(field), types):
                raise ValueError(f"{path}:{i}: missing/invalid {field!r}")
        if rec["seq"] != len(events):
            raise ValueError(f"{path}:{i}: seq {rec['seq']} != {len(events)}")
        events.append(rec)
        by_type[rec["event"]] = by_type.get(rec["event"], 0) + 1
    if events[0]["event"] != "session":
        raise ValueError(f"{path}: first record must be 'session'")
    if events[0].get("version") != SCHEMA_VERSION:
        raise ValueError(f"{path}: schema version {events[0].get('version')} "
                         f"!= {SCHEMA_VERSION}")
    if events[-1]["event"] != "session_end":
        raise ValueError(f"{path}: last record must be 'session_end'")

    pulls_since_select = 0
    rounds_since_select = 0
    for i, rec in enumerate(events, 1):
        if rec["event"] == "round":
            missing = [k for k in ROUND_FIELDS if k not in rec]
            if missing or not isinstance(rec.get("r"), int):
                raise ValueError(f"{path}:{i}: round record missing "
                                 f"{missing or ['r']}")
            pulls_since_select += int(rec["pulls"])
            rounds_since_select += 1
        elif rec["event"] == "select":
            if not isinstance(rec.get("pulls"), int):
                raise ValueError(f"{path}:{i}: select without int 'pulls'")
            if rounds_since_select and pulls_since_select != rec["pulls"]:
                raise ValueError(
                    f"{path}:{i}: select pulls={rec['pulls']} but the "
                    f"{rounds_since_select} preceding round records sum to "
                    f"{pulls_since_select}")
            pulls_since_select = rounds_since_select = 0
        elif rec["event"] == "span":
            if not isinstance(rec.get("name"), str) \
                    or not isinstance(rec.get("dur_s"), (int, float)):
                raise ValueError(f"{path}:{i}: span without name/dur_s")
    return {"events": len(events), "rounds": by_type.get("round", 0),
            "selects": by_type.get("select", 0)}


def validate_exposition(path: str) -> dict:
    """Validate one Prometheus text-exposition file; returns
    ``{"families": F, "samples": S}``."""
    with open(path) as fh:
        text = fh.read()
    if not text.strip():
        raise ValueError(f"{path}: empty exposition")
    typed: dict[str, str] = {}
    inf_bucket: dict[str, int] = {}
    counts: dict[str, int] = {}
    samples = 0
    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            if not _COMMENT.match(line):
                raise ValueError(f"{path}:{i}: malformed comment {line!r}")
            if line.startswith("# TYPE "):
                _, _, name, kind = line.split(None, 3)
                typed[name] = kind
            continue
        m = _SAMPLE.match(line)
        if not m:
            raise ValueError(f"{path}:{i}: malformed sample {line!r}")
        try:
            float(m.group("value"))
        except ValueError:
            raise ValueError(f"{path}:{i}: non-numeric value "
                             f"{m.group('value')!r}") from None
        name = m.group("name")
        family = re.sub(r"_(bucket|sum|count)$", "", name)
        if name not in typed and family not in typed:
            raise ValueError(f"{path}:{i}: sample {name!r} has no TYPE line")
        if name.endswith("_bucket") and 'le="+Inf"' in (m.group("labels")
                                                        or ""):
            key = family + (m.group("labels") or "").replace(',le="+Inf"', "") \
                                                   .replace('le="+Inf"', "")
            if key.endswith("{}"):
                key = key[:-2]
            inf_bucket[key] = int(float(m.group("value")))
        if name.endswith("_count"):
            key = family + (m.group("labels") or "")
            counts[key] = int(float(m.group("value")))
        samples += 1
    for key, c in counts.items():
        if key in inf_bucket and inf_bucket[key] != c:
            raise ValueError(f"{path}: histogram {key}: +Inf bucket "
                             f"{inf_bucket[key]} != _count {c}")
    return {"families": len(typed), "samples": samples}


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.validate "
              "[trace.jsonl ...] [metrics.txt ...]", file=sys.stderr)
        return 2
    for path in argv:
        if path.endswith(".jsonl"):
            summary = validate_trace(path)
        else:
            summary = validate_exposition(path)
        print(f"{path}: OK {json.dumps(summary)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
