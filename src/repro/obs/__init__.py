"""Observability: device-resident telemetry, trace events, serving metrics.

Three layers, lowest first:

* :mod:`repro.obs.telemetry` — the fixed-shape per-round telemetry pytree
  the engine carries through its banded ``lax.scan`` (device-path: pure jnp,
  host-sync-guarded alongside the engine package);
* :mod:`repro.obs.trace` — :class:`TraceSession`, JSONL span/round/select
  events + optional ``jax.profiler`` annotation hooks;
* :mod:`repro.obs.metrics` — counters/histograms with a Prometheus text
  exposition, the :class:`ServerMetrics` bundle of the medoid server, and
  the engine-odometer exposition.

``repro.engine.halving`` imports :mod:`repro.obs.telemetry` from inside the
round loop, so this package sits BELOW the engine in the layering — the
host-side modules (which import :mod:`repro.engine.instrument`) are loaded
lazily to keep that edge acyclic.
"""
from __future__ import annotations

from repro.obs import telemetry

__all__ = ["MetricsRegistry", "ServerMetrics", "TraceSession",
           "instrument_exposition", "telemetry", "telemetry_to_host"]

_LAZY = {
    "TraceSession": ("repro.obs.trace", "TraceSession"),
    "MetricsRegistry": ("repro.obs.metrics", "MetricsRegistry"),
    "ServerMetrics": ("repro.obs.metrics", "ServerMetrics"),
    "instrument_exposition": ("repro.obs.metrics", "instrument_exposition"),
}


def __getattr__(name: str):
    try:
        modname, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}"
                             ) from None
    import importlib

    return getattr(importlib.import_module(modname), attr)


def telemetry_to_host(tel) -> dict:
    """Fetch a device telemetry pytree to host numpy arrays (one transfer
    per leaf, after the answer is already on host — never inside a jitted
    body)."""
    import numpy as np

    return {k: np.asarray(v) for k, v in tel.items()}
