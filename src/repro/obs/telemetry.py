"""Device-resident round telemetry — the per-round numbers the paper plots.

Meddit-style bandit algorithms live or die on *per-round* behavior: how fast
the confidence gap between the incumbent and the runner-up closes, and where
the pull budget goes round by round. This module defines the fixed-shape
telemetry pytree the engine (:func:`repro.engine.run_halving`) optionally
carries through its banded ``lax.scan`` — one row per *executed* round
(scanned rounds plus the output round), every leaf a fixed-shape array, so
telemetry rides the same single XLA program as the answer and never adds a
host sync (this module is under the same host-sync grep guard as the engine
package).

Schema — a dict of arrays, each with leading axis ``R`` = executed rounds
(under ``vmap`` a batch axis is prepended: ``(B, R)``):

======================  =======  ==============================================
key                     dtype    meaning (row r)
======================  =======  ==============================================
``survivors``           int32    scheduled arm count entering round r (s_r)
``num_refs``            int32    scheduled reference draws (t_r)
``pulls``               int32    scheduled distance evaluations (s_r * t_r)
``budget_frac``         float32  cumulative pulls through round r / total
                                 scheduled pulls (reaches 1.0 at the last row)
``alive``               int32    arms with finite estimates (eligible + live;
                                 < s_r under arm masking / ragged padding)
``theta_min``           float32  smallest estimate this round (the incumbent)
``theta_med``           float32  median estimate over the alive arms
``theta_max``           float32  largest finite estimate
``gap``                 float32  runner-up minus incumbent — the quantity
                                 halving must outpace; NaN if < 2 alive arms
======================  =======  ==============================================

``survivors``/``num_refs``/``pulls``/``budget_frac`` are trace-time constants
from the static schedule (so per-round pull sums reconcile *exactly* with
:class:`repro.api.MedoidResult`'s scheduled pull accounting); the theta rows
are measured inside the scan body on the exact masked estimates selection
sees. Pull counts are int32 — fine for every CI-scale workload; past ~2^31
scheduled pulls per round read ``budget_frac`` instead.
"""
from __future__ import annotations

import jax.numpy as jnp

# The telemetry dict's keys, in emission order (shared by the host-side
# consumers in repro.obs.trace / repro.obs.validate).
FIELDS = ("survivors", "num_refs", "pulls", "budget_frac", "alive",
          "theta_min", "theta_med", "theta_max", "gap")

_SCHEDULE_FIELDS = ("survivors", "num_refs", "pulls", "budget_frac")
_DTYPES = {"survivors": jnp.int32, "num_refs": jnp.int32, "pulls": jnp.int32,
           "budget_frac": jnp.float32, "alive": jnp.int32,
           "theta_min": jnp.float32, "theta_med": jnp.float32,
           "theta_max": jnp.float32, "gap": jnp.float32}


def round_stats(theta: jnp.ndarray) -> dict:
    """Summary of one round's masked estimates (pure jnp — scan-body safe).

    ``theta`` is the per-arm estimate vector *after* live/eligibility
    masking (+inf at dead or ineligible positions) — exactly what survivor
    selection sees. Statistics are computed over the finite entries; ``gap``
    is the runner-up minus the incumbent (NaN when fewer than two arms are
    alive — +inf - +inf — which the host layer renders as null).
    """
    st = jnp.sort(theta)                       # ascending, +inf trail
    alive = jnp.sum(jnp.isfinite(st)).astype(jnp.int32)
    last = jnp.maximum(alive - 1, 0)
    return {
        "alive": alive,
        "theta_min": st[0].astype(jnp.float32),
        "theta_med": jnp.take(st, last // 2).astype(jnp.float32),
        "theta_max": jnp.take(st, last).astype(jnp.float32),
        "gap": (st[1] - st[0]).astype(jnp.float32),
    }


def schedule_constants(executed) -> dict:
    """The static (trace-time constant) telemetry columns for the executed
    rounds — scheduled survivor/reference/pull counts and the cumulative
    budget fraction. ``executed`` is the ``Round`` sequence ``[0 .. r_stop]``
    the engine actually runs, so ``sum(pulls)`` here IS the scheduled pull
    count the facade reports."""
    pulls = [r.pulls for r in executed]
    total = max(1, sum(pulls))
    cum, acc = [], 0
    for p in pulls:
        acc += p
        cum.append(acc / total)
    return {
        "survivors": jnp.asarray([r.survivors for r in executed], jnp.int32),
        "num_refs": jnp.asarray([r.num_refs for r in executed], jnp.int32),
        "pulls": jnp.asarray(pulls, jnp.int32),
        "budget_frac": jnp.asarray(cum, jnp.float32),
    }


def empty() -> dict:
    """The zero-round telemetry buffer (n == 1: nothing to halve)."""
    return {k: jnp.zeros((0,), _DTYPES[k]) for k in FIELDS}


def assemble(executed, measured: dict) -> dict:
    """Combine the static schedule columns with the measured theta rows into
    the full telemetry dict (all leaves shape ``(R,)``), ordered by
    :data:`FIELDS`."""
    out = dict(schedule_constants(executed))
    out.update(measured)
    return {k: out[k] for k in FIELDS}
