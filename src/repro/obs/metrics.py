"""Serving metrics: counters + histograms with a Prometheus text exposition.

A deliberately tiny, dependency-free metrics layer (the container has no
prometheus_client, and the serving loop only needs counters and fixed-bucket
histograms). Three pieces:

* :class:`MetricsRegistry` — named metric families (``counter`` /
  ``histogram``) with label sets, a JSON-able :meth:`~MetricsRegistry.snapshot`
  and a Prometheus text-format :meth:`~MetricsRegistry.exposition`;
* :class:`ServerMetrics` — the concrete instrument bundle of the
  continuous-batching :class:`~repro.launch.serve_medoid.MedoidServer`
  (per-bucket request/dispatch counters, queue-wait / batch-occupancy /
  dispatch-latency histograms split compile-vs-steady, pulls per request);
* :func:`instrument_exposition` — the engine-wide trace/dispatch odometers
  (:mod:`repro.engine.instrument`) rendered in the same text format, so the
  launch CLIs' ``--metrics-out`` files are one consistent artifact.

Everything here is host-side bookkeeping over values the engine already
produced — nothing touches device arrays, nothing traces.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Optional

# Default latency buckets (seconds): spans sub-ms steady-state dispatches
# through multi-second first-call compiles.
LATENCY_BUCKETS_S = (0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0, 60.0)
WAIT_BUCKETS_STEPS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0)
OCCUPANCY_BUCKETS = (0.25, 0.5, 0.75, 1.0)
# Winner-gap buckets (distance units): final-round runner-up minus winner.
# A near-zero gap is a *hard* query (halving barely separated the medoid);
# the histogram is the fleet's per-query hardness monitor.
GAP_BUCKETS = (0.001, 0.005, 0.02, 0.05, 0.1, 0.25, 1.0, 4.0)


def _fmt(v: float) -> str:
    """Prometheus-style number formatting (integers stay integral)."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int) or (isinstance(v, float) and v.is_integer()
                              and abs(v) < 1e15):
        return str(int(v))
    return repr(float(v))


def _labels_str(names: tuple, values: tuple) -> str:
    if not names:
        return ""
    inner = ",".join(f'{n}="{v}"' for n, v in zip(names, values))
    return "{" + inner + "}"


@dataclass
class _Counter:
    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counters only go up, got inc({v})")
        self.value += v


@dataclass
class _Histogram:
    bounds: tuple            # ascending upper bounds (an implicit +Inf last)
    counts: list = field(default_factory=list)   # len(bounds) + 1
    total: float = 0.0
    count: int = 0

    def __post_init__(self):
        if not self.counts:
            self.counts = [0] * (len(self.bounds) + 1)

    def observe(self, v: float) -> None:
        v = float(v)
        if math.isnan(v):
            return
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                break
        else:
            self.counts[-1] += 1
        self.total += v
        self.count += 1

    def quantile(self, q: float) -> Optional[float]:
        """Upper-bound estimate of the q-quantile from the fixed buckets
        (None with no observations; overflow-bucket mass falls back to the
        running mean, floored at the last finite bound)."""
        if self.count == 0:
            return None
        target = q * self.count
        cum = 0
        for b, c in zip(self.bounds, self.counts):
            cum += c
            if cum >= target:
                return float(b)
        last = float(self.bounds[-1]) if self.bounds else 0.0
        return max(last, self.total / self.count)


class _Family:
    """One named metric family: a child per label-value tuple."""

    def __init__(self, kind: str, name: str, help: str,
                 labelnames: tuple = (), bounds: Optional[tuple] = None):
        self.kind, self.name, self.help = kind, name, help
        self.labelnames = tuple(labelnames)
        self.bounds = tuple(bounds) if bounds is not None else None
        self.children: dict[tuple, object] = {}

    def labels(self, *values):
        values = tuple(str(v) for v in values)
        if len(values) != len(self.labelnames):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.labelnames}, got {values}")
        child = self.children.get(values)
        if child is None:
            child = (_Counter() if self.kind == "counter"
                     else _Histogram(self.bounds))
            self.children[values] = child
        return child

    # counter-family conveniences for the label-free case
    def inc(self, v: float = 1.0) -> None:
        self.labels().inc(v)

    def observe(self, v: float) -> None:
        self.labels().observe(v)


class MetricsRegistry:
    """A set of metric families with snapshot + Prometheus exposition."""

    def __init__(self):
        self._families: dict[str, _Family] = {}

    def counter(self, name: str, help: str, labelnames: tuple = ()) -> _Family:
        return self._register(_Family("counter", name, help, labelnames))

    def histogram(self, name: str, help: str, labelnames: tuple = (),
                  buckets: Iterable[float] = LATENCY_BUCKETS_S) -> _Family:
        return self._register(
            _Family("histogram", name, help, labelnames,
                    bounds=tuple(sorted(float(b) for b in buckets))))

    def _register(self, fam: _Family) -> _Family:
        if fam.name in self._families:
            raise ValueError(f"metric {fam.name!r} already registered")
        self._families[fam.name] = fam
        return fam

    def snapshot(self) -> dict:
        """JSON-able state of every family (counters: value per label tuple;
        histograms: per-bucket counts + sum + count)."""
        out: dict = {}
        for fam in self._families.values():
            fd: dict = {"type": fam.kind, "help": fam.help, "series": []}
            for values, child in sorted(fam.children.items()):
                labels = dict(zip(fam.labelnames, values))
                if fam.kind == "counter":
                    fd["series"].append({"labels": labels,
                                         "value": child.value})
                else:
                    fd["series"].append({
                        "labels": labels,
                        "buckets": dict(zip([str(b) for b in fam.bounds]
                                            + ["+Inf"], child.counts)),
                        "sum": child.total, "count": child.count})
            out[fam.name] = fd
        return out

    def exposition(self) -> str:
        """Prometheus text exposition format (one HELP/TYPE block per
        family, cumulative ``_bucket`` series for histograms)."""
        lines: list[str] = []
        for fam in self._families.values():
            lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for values, child in sorted(fam.children.items()):
                ls = _labels_str(fam.labelnames, values)
                if fam.kind == "counter":
                    lines.append(f"{fam.name}{ls} {_fmt(child.value)}")
                    continue
                cum = 0
                for b, c in zip(fam.bounds, child.counts):
                    cum += c
                    bls = _labels_str(fam.labelnames + ("le",),
                                      values + (_fmt(b),))
                    lines.append(f"{fam.name}_bucket{bls} {cum}")
                bls = _labels_str(fam.labelnames + ("le",),
                                  values + ("+Inf",))
                lines.append(f"{fam.name}_bucket{bls} {child.count}")
                lines.append(f"{fam.name}_sum{ls} {_fmt(child.total)}")
                lines.append(f"{fam.name}_count{ls} {_fmt(child.count)}")
        return "\n".join(lines) + ("\n" if lines else "")


class ServerMetrics:
    """The MedoidServer's instrument bundle, labeled by shape bucket
    (``"<n_bucket>x<d>"``). ``phase`` on dispatch metrics separates first
    dispatches that traced a new XLA program (``compile``) from cached
    steady-state dispatches (``steady``) — the split the one-program
    refactor exists to optimize."""

    def __init__(self):
        self.registry = MetricsRegistry()
        r = self.registry
        self.requests = r.counter(
            "medoid_requests_total", "medoid queries admitted", ("bucket",))
        self.answered = r.counter(
            "medoid_answered_total", "medoid queries answered", ("bucket",))
        self.dispatches = r.counter(
            "medoid_dispatches_total",
            "ragged engine dispatches", ("bucket", "phase"))
        self.pulls = r.counter(
            "medoid_pulls_total",
            "scheduled distance evaluations charged to answered requests",
            ("bucket",))
        self.queue_wait = r.histogram(
            "medoid_queue_wait_steps", "scheduler steps spent queued",
            ("bucket",), buckets=WAIT_BUCKETS_STEPS)
        self.occupancy = r.histogram(
            "medoid_batch_occupancy",
            "real requests / batch slots per dispatch",
            ("bucket",), buckets=OCCUPANCY_BUCKETS)
        self.latency = r.histogram(
            "medoid_dispatch_seconds", "wall time of one ragged dispatch",
            ("bucket", "phase"), buckets=LATENCY_BUCKETS_S)
        self.winner_gap = r.histogram(
            "medoid_winner_gap",
            "final-round runner-up minus winner estimate (query hardness)",
            ("bucket",), buckets=GAP_BUCKETS)
        self.shed = r.counter(
            "medoid_shed_total",
            "requests shed unanswered (deadline hopeless at scheduling time)",
            ("bucket",))
        self.deadline = r.counter(
            "medoid_deadline_total",
            "deadlined requests answered, by whether they made it",
            ("bucket", "outcome"))

    def record_submit(self, bucket: str) -> None:
        self.requests.labels(bucket).inc()

    def record_gap(self, bucket: str, gap: float) -> None:
        """One answered query's final-round winner gap (NaN — fewer than
        two alive arms — is dropped by the histogram)."""
        self.winner_gap.labels(bucket).observe(gap)

    def record_shed(self, bucket: str) -> None:
        self.shed.labels(bucket).inc()

    def record_deadline(self, bucket: str, met: bool) -> None:
        self.deadline.labels(bucket, "met" if met else "missed").inc()

    def record_dispatch(self, bucket: str, *, wall_s: float, batch: int,
                        slots: int, pulls_per_request: int,
                        waits: Iterable[int], compiled: bool) -> None:
        """Account one served batch: ``batch`` real requests in ``slots``
        padded slots, ``compiled`` = this dispatch traced a new program."""
        phase = "compile" if compiled else "steady"
        self.dispatches.labels(bucket, phase).inc()
        self.latency.labels(bucket, phase).observe(wall_s)
        self.occupancy.labels(bucket).observe(batch / max(1, slots))
        for w in waits:
            self.queue_wait.labels(bucket).observe(float(w))
        self.answered.labels(bucket).inc(batch)
        self.pulls.labels(bucket).inc(pulls_per_request * batch)

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def exposition(self) -> str:
        return self.registry.exposition()


def instrument_exposition() -> str:
    """The engine-wide trace/dispatch odometers
    (:mod:`repro.engine.instrument`) in Prometheus text format — appended to
    every ``--metrics-out`` artifact so a metrics file alone shows whether
    traffic was compile-bound or steady-state."""
    from repro.engine import instrument

    c = instrument.counters()
    lines = ["# HELP engine_traces_total XLA programs traced per entry point",
             "# TYPE engine_traces_total counter"]
    for kind, v in c["traces"].items():
        lines.append(f'engine_traces_total{{kind="{kind}"}} {v}')
    lines += ["# HELP engine_dispatches_total host-side dispatches per "
              "entry point",
              "# TYPE engine_dispatches_total counter"]
    for kind, v in c["dispatches"].items():
        lines.append(f'engine_dispatches_total{{kind="{kind}"}} {v}')
    return "\n".join(lines) + "\n"
