"""Clustering against the continuous-batching medoid service.

The refinement phase of :func:`repro.api.kmedoids` is a
stream of independent single-medoid queries with heterogeneous sizes — which
is exactly the workload :class:`repro.launch.serve_medoid.MedoidServer`
exists for. :class:`ServiceRefiner` adapts the refiner hook to submit each
cluster subproblem as a service request, so a clustering job shares the
server's bucketed dispatch, fixed-slot batching, and compile-odometer
guarantees with every other tenant's medoid traffic (and its per-request
accounting: the pulls reported are the server's scheduled pulls).

:class:`ClusterService` is the observability facade over a live server: a
tiny route table (``/stats``, ``/metrics``, ``/buckets``, and ``/stream``
when a :class:`ClusterStream` is attached) serving the scheduler
accounting, the JSON metrics snapshot, and the Prometheus text
exposition — the same payloads an HTTP front-end would mount, minus the
HTTP (the container ships no web stack, and the tests exercise the routes
directly).

:class:`ClusterStream` is the streaming maintenance layer: fit once with
the full BUILD/refine/SWAP pipeline, then ``add(points)`` assigns arrivals
to their nearest medoid through a padded jitted program
(:func:`repro.cluster.kmedoids.assign_to_medoids` — one compiled program
per arrival bucket) and re-refines ONLY the clusters that received points
(one bounded ragged sweep through the same refiner hook the fit used),
instead of re-clustering from scratch.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.cluster.kmedoids import (KMedoidsResult, _kmedoids_impl,
                                    assign_to_medoids, make_direct_refiner)
from repro.core.bucketing import DEFAULT_MIN_BUCKET


class ServiceRefiner:
    """Refiner hook that routes per-cluster medoid queries through a
    ``MedoidServer``. The server owns its key stream and budget policy
    (``budget_per_arm * n_bucket`` per request — the same shape as the
    direct refiner), so the ``key`` argument of the hook is unused."""

    def __init__(self, server):
        self.server = server

    def __call__(self, arrays: list, key: jax.Array) -> tuple[list, int]:
        rids = [self.server.submit(a) for a in arrays]
        self.server.drain()
        answered = [self.server.done[r] for r in rids]
        return ([int(r.medoid) for r in answered],
                sum(r.pulls for r in answered))


class ClusterStream:
    """Streaming cluster maintenance over a fitted k-medoids model.

    The constructor runs the full pipeline once (identical to
    :func:`repro.api.kmedoids` — same key policy, same result). Each
    :meth:`add` then:

    1. assigns the arriving points to their nearest current medoid
       (padded jitted program; one compilation per arrival bucket);
    2. re-refines ONLY the affected clusters — the ones that received
       points — with one bounded ragged sweep through the refiner hook
       (direct bucketed dispatches by default; pass
       ``refiner=ServiceRefiner(server)`` to ride a live MedoidServer);
    3. re-assigns the members of those clusters against the updated
       medoids (other clusters are untouched — bounded maintenance, not a
       global re-fit; :meth:`refit` re-runs the full pipeline when drift
       accumulates).

    Medoids are stable indices into the growing point store, and every
    distance evaluation is accounted in :attr:`assign_pulls` /
    :attr:`refine_pulls` on top of the initial fit's.
    """

    def __init__(self, data, k: int, key: jax.Array, *,
                 metric: str = "l2", backend: str = "reference",
                 refine_budget_per_arm: int = 20,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 refiner=None, **kwargs):
        self.metric = metric
        self.backend = backend
        self.min_bucket = min_bucket
        self.k = k
        self._key = key
        self._refiner = refiner if refiner is not None else \
            make_direct_refiner(metric=metric, backend=backend,
                                budget_per_arm=refine_budget_per_arm,
                                min_bucket=min_bucket)
        self.fit = _kmedoids_impl(
            data, k, key, metric=metric, backend=backend,
            refine_budget_per_arm=refine_budget_per_arm,
            min_bucket=min_bucket, refiner=refiner, **kwargs)
        self.data = np.asarray(data, np.float32).copy()
        self.labels = self.fit.labels.copy()
        self.medoids = list(self.fit.medoids)   # point indices, stable
        self.arrivals = 0
        self.batches = 0
        self.assign_pulls = 0
        self.refine_pulls = 0
        self.medoid_updates = 0

    @property
    def n(self) -> int:
        return int(self.data.shape[0])

    @property
    def pulls(self) -> int:
        """Total distance evaluations: initial fit + streaming maintenance."""
        return self.fit.pulls + self.assign_pulls + self.refine_pulls

    def add(self, points) -> dict:
        """Ingest ``points (m, d)``; returns what the maintenance pass did:
        ``{"assigned": (m,) labels, "affected": [cluster slots],
        "medoid_updates": int, "pulls": int}``."""
        points = np.asarray(points, np.float32)
        if points.ndim != 2 or points.shape[1] != self.data.shape[1]:
            raise ValueError(f"expected (m, {self.data.shape[1]}) points, "
                             f"got shape {points.shape}")
        pulls0 = self.assign_pulls + self.refine_pulls
        labels_new, _, p = assign_to_medoids(
            points, self.data[self.medoids], metric=self.metric,
            backend=self.backend, min_bucket=self.min_bucket)
        self.assign_pulls += p
        self.data = np.concatenate([self.data, points])
        self.labels = np.concatenate([self.labels, labels_new])
        self.arrivals += int(points.shape[0])
        self.batches += 1

        affected = sorted(set(labels_new.tolist()))
        members = [(c, np.flatnonzero(self.labels == c)) for c in affected]
        members = [(c, mem) for c, mem in members if mem.size > 0]
        updates = 0
        if members:
            key = jax.random.fold_in(self._key, 3 + self.batches)
            locals_, p = self._refiner(
                [self.data[mem] for _, mem in members], key)
            self.refine_pulls += p
            for (c, mem), loc in zip(members, locals_):
                g = int(mem[int(loc)])
                if g != self.medoids[c]:
                    self.medoids[c] = g
                    updates += 1
            if updates:
                # bounded re-assignment: only the affected clusters'
                # members are re-priced against the updated medoids
                mem_all = np.concatenate([mem for _, mem in members])
                lab, _, p = assign_to_medoids(
                    self.data[mem_all], self.data[self.medoids],
                    metric=self.metric, backend=self.backend,
                    min_bucket=self.min_bucket)
                self.assign_pulls += p
                self.labels[mem_all] = lab
        self.medoid_updates += updates
        return {"assigned": labels_new, "affected": affected,
                "medoid_updates": updates,
                "pulls": self.assign_pulls + self.refine_pulls - pulls0}

    def refit(self, **kwargs) -> KMedoidsResult:
        """Full re-clustering of the current store (fresh BUILD/refine/SWAP
        under a fresh fold of the stream key) — the escape hatch when
        bounded maintenance has drifted. Resets labels and medoids."""
        # fold constant 2 is reserved for SWAP inside the fit; batches fold
        # from 4 upward — 3 is the refit lane
        self._key = jax.random.fold_in(self._key, 3)
        self.fit = _kmedoids_impl(
            self.data, self.k, self._key, metric=self.metric,
            backend=self.backend, min_bucket=self.min_bucket,
            refiner=self._refiner, **kwargs)
        self.labels = self.fit.labels.copy()
        self.medoids = list(self.fit.medoids)
        return self.fit

    def cost(self) -> float:
        """Current summed distance to assigned medoids (host recompute —
        an observability number, not on the serving path)."""
        _, d1, _ = assign_to_medoids(
            self.data, self.data[self.medoids], metric=self.metric,
            backend=self.backend, min_bucket=self.min_bucket)
        return float(d1.sum())

    def stats(self) -> dict:
        return {
            "n": self.n, "k": self.k, "arrivals": self.arrivals,
            "batches": self.batches, "medoids": list(self.medoids),
            "medoid_updates": self.medoid_updates,
            "fit_pulls": self.fit.pulls,
            "assign_pulls": self.assign_pulls,
            "refine_pulls": self.refine_pulls,
            "total_pulls": self.pulls,
        }


class ClusterService:
    """Route-level view of a :class:`~repro.launch.serve_medoid.MedoidServer`
    (observability endpoints a front-end would mount verbatim)::

        svc = ClusterService(server, stream=stream)
        svc.handle("/stats")     # scheduler accounting + metrics snapshot
        svc.handle("/metrics")   # Prometheus text exposition (str)
        svc.handle("/buckets")   # compiled-bucket inventory
        svc.handle("/stream")    # streaming-maintenance accounting

    ``routes()`` lists the table; unknown paths raise ``KeyError`` (a 404).
    The ``/stream`` route exists only when a :class:`ClusterStream` is
    attached (at construction or via :meth:`attach_stream`).
    """

    def __init__(self, server, stream: Optional[ClusterStream] = None):
        self.server = server
        self.stream = None
        self._routes = {"/stats": self.stats, "/metrics": self.metrics,
                        "/buckets": self.buckets}
        if stream is not None:
            self.attach_stream(stream)

    def attach_stream(self, stream: ClusterStream) -> None:
        """Mount a live :class:`ClusterStream` under ``/stream``."""
        self.stream = stream
        self._routes["/stream"] = self.stream_stats

    def routes(self) -> tuple:
        return tuple(sorted(self._routes))

    def handle(self, path: str):
        try:
            route = self._routes[path]
        except KeyError:
            raise KeyError(f"no route {path!r}; one of {self.routes()}"
                           ) from None
        return route()

    def stats(self) -> dict:
        """The ``/stats`` payload: the server's scheduler accounting plus
        the JSON metrics snapshot (one response answers both "is the queue
        healthy" and "what are the per-bucket latency/wait distributions")."""
        return {**self.server.stats(), "metrics": self.server.metrics()}

    def metrics(self) -> str:
        """The ``/metrics`` payload: Prometheus text exposition."""
        return self.server.exposition()

    def buckets(self) -> dict:
        """The ``/buckets`` payload: compiled-shape inventory."""
        return {"buckets": sorted(f"{nb}x{d}"
                                  for nb, d in self.server.buckets_seen),
                "recompiles": self.server.recompiles,
                "dispatches": self.server.dispatches}

    def stream_stats(self) -> dict:
        """The ``/stream`` payload: streaming-maintenance accounting."""
        if self.stream is None:
            raise KeyError("no ClusterStream attached")
        return self.stream.stats()


def kmedoids_via_service(data, k: int, key: jax.Array, *,
                         server: Optional[object] = None,
                         metric: str = "l2", backend: str = "reference",
                         refine_budget_per_arm: int = 20, max_batch: int = 8,
                         **kwargs) -> tuple[KMedoidsResult, object]:
    """Run bandit k-medoids with refinement served by a continuous-batching
    ``MedoidServer`` (a fresh one unless ``server`` is passed — pass a live
    server to co-schedule clustering with other medoid traffic). Returns
    ``(result, server)`` so callers can read the server's dispatch stats."""
    from repro.launch.serve_medoid import MedoidServer

    srv = server
    if srv is None:
        srv = MedoidServer(metric=metric, backend=backend,
                           budget_per_arm=refine_budget_per_arm,
                           max_batch=max_batch)
    result = _kmedoids_impl(data, k, key, metric=metric, backend=backend,
                            refine_budget_per_arm=refine_budget_per_arm,
                            refiner=ServiceRefiner(srv), **kwargs)
    return result, srv
