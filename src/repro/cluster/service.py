"""Clustering against the continuous-batching medoid service.

The refinement phase of :func:`repro.api.kmedoids` is a
stream of independent single-medoid queries with heterogeneous sizes — which
is exactly the workload :class:`repro.launch.serve_medoid.MedoidServer`
exists for. :class:`ServiceRefiner` adapts the refiner hook to submit each
cluster subproblem as a service request, so a clustering job shares the
server's bucketed dispatch, fixed-slot batching, and compile-odometer
guarantees with every other tenant's medoid traffic (and its per-request
accounting: the pulls reported are the server's scheduled pulls).

:class:`ClusterService` is the observability facade over a live server: a
tiny route table (``/stats``, ``/metrics``, ``/buckets``) serving the
scheduler accounting, the JSON metrics snapshot, and the Prometheus text
exposition — the same payloads an HTTP front-end would mount, minus the
HTTP (the container ships no web stack, and the tests exercise the routes
directly).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.cluster.kmedoids import KMedoidsResult, _kmedoids_impl


class ServiceRefiner:
    """Refiner hook that routes per-cluster medoid queries through a
    ``MedoidServer``. The server owns its key stream and budget policy
    (``budget_per_arm * n_bucket`` per request — the same shape as the
    direct refiner), so the ``key`` argument of the hook is unused."""

    def __init__(self, server):
        self.server = server

    def __call__(self, arrays: list, key: jax.Array) -> tuple[list, int]:
        rids = [self.server.submit(a) for a in arrays]
        self.server.drain()
        answered = [self.server.done[r] for r in rids]
        return ([int(r.medoid) for r in answered],
                sum(r.pulls for r in answered))


class ClusterService:
    """Route-level view of a :class:`~repro.launch.serve_medoid.MedoidServer`
    (observability endpoints a front-end would mount verbatim)::

        svc = ClusterService(server)
        svc.handle("/stats")     # scheduler accounting + metrics snapshot
        svc.handle("/metrics")   # Prometheus text exposition (str)
        svc.handle("/buckets")   # compiled-bucket inventory

    ``routes()`` lists the table; unknown paths raise ``KeyError`` (a 404).
    """

    def __init__(self, server):
        self.server = server
        self._routes = {"/stats": self.stats, "/metrics": self.metrics,
                        "/buckets": self.buckets}

    def routes(self) -> tuple:
        return tuple(sorted(self._routes))

    def handle(self, path: str):
        try:
            route = self._routes[path]
        except KeyError:
            raise KeyError(f"no route {path!r}; one of {self.routes()}"
                           ) from None
        return route()

    def stats(self) -> dict:
        """The ``/stats`` payload: the server's scheduler accounting plus
        the JSON metrics snapshot (one response answers both "is the queue
        healthy" and "what are the per-bucket latency/wait distributions")."""
        return {**self.server.stats(), "metrics": self.server.metrics()}

    def metrics(self) -> str:
        """The ``/metrics`` payload: Prometheus text exposition."""
        return self.server.exposition()

    def buckets(self) -> dict:
        """The ``/buckets`` payload: compiled-shape inventory."""
        return {"buckets": sorted(f"{nb}x{d}"
                                  for nb, d in self.server.buckets_seen),
                "recompiles": self.server.recompiles,
                "dispatches": self.server.dispatches}


def kmedoids_via_service(data, k: int, key: jax.Array, *,
                         server: Optional[object] = None,
                         metric: str = "l2", backend: str = "reference",
                         refine_budget_per_arm: int = 20, max_batch: int = 8,
                         **kwargs) -> tuple[KMedoidsResult, object]:
    """Run bandit k-medoids with refinement served by a continuous-batching
    ``MedoidServer`` (a fresh one unless ``server`` is passed — pass a live
    server to co-schedule clustering with other medoid traffic). Returns
    ``(result, server)`` so callers can read the server's dispatch stats."""
    from repro.launch.serve_medoid import MedoidServer

    srv = server
    if srv is None:
        srv = MedoidServer(metric=metric, backend=backend,
                           budget_per_arm=refine_budget_per_arm,
                           max_batch=max_batch)
    result = _kmedoids_impl(data, k, key, metric=metric, backend=backend,
                            refine_budget_per_arm=refine_budget_per_arm,
                            refiner=ServiceRefiner(srv), **kwargs)
    return result, srv
