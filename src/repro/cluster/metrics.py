"""Clustering quality metrics (dependency-free numpy implementations)."""
from __future__ import annotations

import numpy as np


def adjusted_rand_index(labels_a, labels_b) -> float:
    """Adjusted Rand Index between two labelings of the same points.

    1.0 = identical partitions (up to label permutation), ~0.0 = chance
    agreement. Hubert & Arabie's permutation-model adjustment computed from
    the contingency table — no sklearn dependency.
    """
    a = np.asarray(labels_a).ravel()
    b = np.asarray(labels_b).ravel()
    if a.shape != b.shape:
        raise ValueError(f"labelings must cover the same points, got "
                         f"{a.shape} vs {b.shape}")
    n = a.size
    if n < 2:
        return 1.0
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    table = np.zeros((ai.max() + 1, bi.max() + 1), dtype=np.int64)
    np.add.at(table, (ai, bi), 1)

    def comb2(x):
        x = x.astype(np.float64)
        return (x * (x - 1.0) / 2.0).sum()

    sum_ij = comb2(table)
    sum_a = comb2(table.sum(axis=1))
    sum_b = comb2(table.sum(axis=0))
    total = n * (n - 1.0) / 2.0
    expected = sum_a * sum_b / total
    maximum = (sum_a + sum_b) / 2.0
    if maximum == expected:          # both partitions trivial (all one / all n)
        return 1.0
    return float((sum_ij - expected) / (maximum - expected))


def clustering_cost(d_to_medoid) -> float:
    """Total assignment cost: sum of each point's distance to its medoid."""
    return float(np.asarray(d_to_medoid, dtype=np.float64).sum())
