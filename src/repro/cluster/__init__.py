"""Bandit k-medoids clustering subsystem on the correlated-SH engine."""
from repro.cluster.kmedoids import (
    KMedoidsResult,
    bandit_kmedoids,
    make_direct_refiner,
)
from repro.cluster.metrics import adjusted_rand_index, clustering_cost
from repro.cluster.pam_exact import (
    PAMResult,
    distance_matrix,
    pam_build,
    pam_exact,
    pam_pulls,
    pam_swap,
)
from repro.cluster.service import ServiceRefiner, kmedoids_via_service

__all__ = [
    "KMedoidsResult", "PAMResult", "ServiceRefiner", "adjusted_rand_index",
    "bandit_kmedoids", "clustering_cost", "distance_matrix",
    "kmedoids_via_service", "make_direct_refiner", "pam_build", "pam_exact",
    "pam_pulls", "pam_swap",
]
