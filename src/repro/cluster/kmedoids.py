"""Bandit k-medoids clustering on the correlated-SH engine.

The paper's primitive — adaptive medoid identification in O(n log n)
distance evaluations — is exactly the inner loop of k-medoids, which is how
BanditPAM (Tiwari et al., NeurIPS 2020) and BanditPAM++ (2023) framed the
clustering problem. This module builds the full pipeline out of the layers
the repo already has, instead of re-deriving any of them:

* **BUILD** (greedy seeding): k correlated-SH argmin problems. Step 0 *is*
  the single-medoid problem and literally calls the same jitted single-query
  engine as :func:`repro.api.find_medoid` (so a k=1 BUILD is bit-identical
  to the paper engine by construction). Steps t >= 1 run
  :func:`repro.engine.run_halving` with the BanditPAM ``build_delta``
  estimator: an arm i's value over a shared reference draw J is
  ``sum_{j in J} min(d1_j, d(x_i, x_j))`` where ``d1`` is the cached
  distance to the nearest already-chosen medoid — the correlation trick
  applies unchanged because all arms share J (and the ``d1_J`` gather).

* **Ragged per-cluster refinement**: alternate-style sweeps. Each cluster's
  medoid update is a pure single-medoid problem over its members, and
  cluster sizes are heterogeneous — so the per-cluster subproblems are
  routed through :func:`repro.core.corr_sh.ragged_medoids` via the
  power-of-two bucketing planner (clusters are just another ragged traffic
  source; the compile odometer bounds hold here too). Per-cluster caching:
  only clusters whose membership changed since the previous sweep recompute.

* **SWAP** (FasterPAM-style bandit local search): swap-in candidates are the
  arms; one shared reference draw J yields, per candidate c, the swap deltas
  against ALL k medoids at once from the cached nearest/second-nearest
  distances:

      delta(c, i) = sum_{j in J} min(d(c,j) - d1_j, 0)
                  + sum_{j in J, nearest_j = i} [ min(d(c,j), d2_j) - d1_j
                                                  - min(d(c,j) - d1_j, 0) ]

  (a (C, t) block, a (t, k) one-hot segment sum — entirely on-device). The
  arm value is ``min_i delta(c, i)`` and correlated sequential halving prunes
  candidates round by round. The winning swap is verified with an *exact*
  delta (one n-vector of distances) before being applied; the ``(n, k)``
  medoid-distance cache then updates incrementally — only the swapped
  column is recomputed, and nearest/second-nearest fall out of a top-2.

Pull accounting is explicit and scheduled (never estimated), so benchmarks
and tests can assert the O(n log n)-vs-O(n^2) gap against exact PAM
(:mod:`repro.cluster.pam_exact`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (get_backend, plan_buckets, pack_queries,
                        round_schedule, schedule_pulls)
from repro.core.bucketing import DEFAULT_MIN_BUCKET, bucket_n, next_pow2
from repro.core.corr_sh import _medoid_impl, ragged_medoids
from repro.deprecation import warn_once
from repro.engine import (HalvingProblem, build_delta, run_halving,
                          swap_delta)
from repro.engine.programs import donation_enabled

# refiner hook: (cluster member arrays, key) -> (local medoid indices, pulls).
# The default runs bucketed ragged dispatches in-process; the service layer
# (repro.cluster.service) substitutes a continuous-batching MedoidServer.
Refiner = Callable[[list, jax.Array], tuple[list, int]]


@dataclasses.dataclass
class KMedoidsResult:
    medoids: list[int]            # k point indices (cluster slot order)
    labels: np.ndarray            # (n,) cluster slot per point
    cost: float                   # sum of distances to assigned medoids
    pulls: int                    # total scheduled distance evaluations
    build_pulls: int
    assign_pulls: int
    refine_pulls: int
    swap_pulls: int
    swaps: int                    # accepted SWAP moves
    refine_updates: int           # per-cluster medoid changes during sweeps
    k: int = 0
    metric: str = "l2"
    backend: str = "reference"


# --------------------------------------------------------------------------
# jitted phase kernels — one compilation per (n, d, k, budget, metric,
# backend) signature, reused across BUILD steps / SWAP rounds / sweeps.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("budget", "metric", "backend"))
def _build_step(data: jnp.ndarray, d1: jnp.ndarray, chosen: jnp.ndarray,
                key: jax.Array, *, budget: int, metric: str,
                backend: str) -> jnp.ndarray:
    """One BUILD greedy step: ``run_halving`` with the BanditPAM
    ``build_delta`` estimator (``sum_j min(d1_j, d(i, j))`` — the cached
    nearest-medoid distance caps every reference's contribution). Arms
    already chosen as medoids are masked out via ``arm_mask``."""
    rounds = round_schedule(data.shape[0], budget)
    problem = HalvingProblem(data, build_delta(backend, metric, d1=d1),
                             arm_mask=~chosen)
    return run_halving(problem, rounds, backend, key=key).winner


@functools.partial(jax.jit,
                   static_argnames=("k", "budget", "metric", "backend"))
def _build_scan(data: jnp.ndarray, m0: jnp.ndarray, key_build: jax.Array, *,
                k: int, budget: int, metric: str, backend: str):
    """BUILD steps 1..k-1 as ONE device-resident program: a ``lax.scan``
    whose carry is the ``(d1, chosen)`` cache pair. Each step runs the same
    traced round loop as :func:`_build_step` (``fold_in(key_build, t)`` per
    step, identical to the per-step host loop it replaces), updates the
    nearest-medoid distance cache ``d1`` from the winner's distance row, and
    marks the winner chosen — per-step winners never visit the host.
    Returns ``(meds (k,), d1 (n,))``."""
    n = data.shape[0]
    pw = get_backend(backend).pairwise(metric)
    rounds = round_schedule(n, budget)
    d1 = jnp.minimum(jnp.full((n,), jnp.inf, jnp.float32),
                     pw(data[m0][None, :], data)[0])
    chosen = jnp.zeros((n,), bool).at[m0].set(True)

    def step(carry, t):
        d1, chosen = carry
        kt = jax.random.fold_in(key_build, t)
        problem = HalvingProblem(data, build_delta(backend, metric, d1=d1),
                                 arm_mask=~chosen)
        m = run_halving(problem, rounds, backend, key=kt).winner
        d1 = jnp.minimum(d1, pw(data[m][None, :], data)[0])
        chosen = chosen.at[m].set(True)
        return (d1, chosen), m

    (d1, _), ms = jax.lax.scan(step, (d1, chosen),
                               jnp.arange(1, k, dtype=jnp.int32))
    return jnp.concatenate([m0[None].astype(jnp.int32), ms]), d1


@functools.partial(jax.jit, static_argnames=("metric", "backend"))
def _assign(data: jnp.ndarray, med_idx: jnp.ndarray, *, metric: str,
            backend: str):
    """Full (n, k) medoid-distance cache + nearest/second-nearest summary."""
    pw = get_backend(backend).pairwise(metric)
    dmat = pw(data, data[med_idx])                            # (n, k)
    return (dmat,) + _top2_of(dmat)


def _top2_of(dmat: jnp.ndarray):
    """(d1, d2, nearest) from the (n, k) cache — d2 = +inf when k == 1."""
    if dmat.shape[1] == 1:
        d1 = dmat[:, 0]
        return d1, jnp.full_like(d1, jnp.inf), jnp.zeros(d1.shape, jnp.int32)
    vals, ids = jax.lax.top_k(-dmat, 2)
    return -vals[:, 0], -vals[:, 1], ids[:, 0].astype(jnp.int32)


_top2 = jax.jit(_top2_of)


@functools.partial(jax.jit, static_argnames=("metric", "backend"))
def _assign_points(points: jnp.ndarray, med_rows: jnp.ndarray, *,
                   metric: str, backend: str):
    """Nearest medoid per row of ``points (m, k-free)``: ``(labels (m,),
    d1 (m,))`` against the medoid rows ``(k, d)``."""
    pw = get_backend(backend).pairwise(metric)
    dmat = pw(points, med_rows)                               # (m, k)
    return jnp.argmin(dmat, axis=1).astype(jnp.int32), jnp.min(dmat, axis=1)


def assign_to_medoids(points, med_rows, *, metric: str = "l2",
                      backend: str = "reference",
                      min_bucket: int = DEFAULT_MIN_BUCKET):
    """Assign arriving points to their nearest medoid, padded to a
    power-of-two arrival bucket so any arrival-size stream reuses one
    compiled program per ``(m_bucket, k, d)`` signature (the streaming
    analogue of the serving layer's shape buckets). Returns
    ``(labels (m,) np.int32, d1 (m,) np.float32, pulls)`` — pulls charge
    the padded rows too (they ran)."""
    points = jnp.asarray(points, jnp.float32)
    med_rows = jnp.asarray(med_rows, jnp.float32)
    if points.ndim != 2 or med_rows.ndim != 2:
        raise ValueError(f"expected (m, d) points and (k, d) medoid rows, "
                         f"got {points.shape} and {med_rows.shape}")
    m = int(points.shape[0])
    mb = bucket_n(max(1, m), min_bucket)
    padded = jnp.zeros((mb, points.shape[1]), jnp.float32).at[:m].set(points)
    labels, d1 = _assign_points(padded, med_rows, metric=metric,
                                backend=backend)
    return (np.asarray(labels[:m]), np.asarray(d1[:m]),
            mb * int(med_rows.shape[0]))


@functools.partial(jax.jit,
                   static_argnames=("budget", "k", "metric", "backend"))
def _swap_argmin(data: jnp.ndarray, d1: jnp.ndarray, d2: jnp.ndarray,
                 nearest: jnp.ndarray, chosen: jnp.ndarray, key: jax.Array,
                 *, budget: int, k: int, metric: str, backend: str):
    """One correlated-SH pass over swap-in candidates: ``run_halving`` with
    the FasterPAM ``swap_delta`` estimator (one shared reference draw prices
    all k swaps of every surviving candidate). Returns ``(candidate, medoid
    slot, estimated per-reference delta)`` for the best pair — the winner's
    ``(C, k)`` delta block rides the outcome's ``aux``."""
    rounds = round_schedule(data.shape[0], budget)
    problem = HalvingProblem(
        data, swap_delta(backend, metric, d1=d1, d2=d2, nearest=nearest, k=k),
        arm_mask=~chosen)
    out = run_halving(problem, rounds, backend, key=key)
    slot = jnp.argmin(out.aux[out.winner_pos]).astype(jnp.int32)
    return out.winner, slot, out.theta[out.winner_pos]


@functools.partial(jax.jit, static_argnames=("metric", "backend"))
def _exact_swap_delta(data: jnp.ndarray, cand: jnp.ndarray,
                      slot: jnp.ndarray, d1: jnp.ndarray, d2: jnp.ndarray,
                      nearest: jnp.ndarray, *, metric: str, backend: str):
    """Exact cost delta of swapping medoid ``slot`` for point ``cand`` — one
    n-vector of distances (the verification step before any swap is
    applied). Returns ``(delta, d(cand, .))``; the distance row is reused to
    update the cache column when the swap is accepted."""
    pw = get_backend(backend).pairwise(metric)
    dc = pw(data[cand][None, :], data)[0]                     # (n,)
    mine = nearest == slot
    delta = jnp.sum(jnp.where(mine, jnp.minimum(dc, d2) - d1,
                              jnp.minimum(dc - d1, 0.0)))
    return delta, dc


def _swap_sweep_impl(data: jnp.ndarray, dmat: jnp.ndarray, meds: jnp.ndarray,
                     key_swap: jax.Array, *, max_rounds: int, k: int,
                     budget: int, metric: str, backend: str):
    """The whole SWAP phase as ONE device-resident program.

    A ``lax.scan`` over ``max_rounds`` candidate sweeps carrying the
    ``(n, k)`` assignment cache ``dmat`` (donated — the caller's copy is
    consumed), the medoid slots, and the accept/reject state machine of the
    host loop it replaces: a round's bandit winner is verified against the
    exact incumbent-delta vector on device, an accepted swap rewrites one
    cache column and resets the rejection counter, and two consecutive
    rejections latch ``done`` (later rounds are masked no-ops; their keys
    are per-round ``fold_in``\\ s, so skipping costs nothing and perturbs
    nothing). Winners, deltas, and the acceptance tolerance never visit the
    host. Returns ``(meds, labels, cost, swaps, executed)`` — ``executed``
    is the number of non-masked rounds, for exact pull accounting.
    """
    n = data.shape[0]
    pw = get_backend(backend).pairwise(metric)
    rounds = round_schedule(n, budget)

    def body(carry, rnd):
        dmat, meds, swaps, rejections, executed, done = carry
        d1, d2, nearest = _top2_of(dmat)
        chosen = jnp.zeros((n,), bool).at[meds].set(True)
        problem = HalvingProblem(
            data, swap_delta(backend, metric, d1=d1, d2=d2, nearest=nearest,
                             k=k), arm_mask=~chosen)
        out = run_halving(problem, rounds, backend,
                          key=jax.random.fold_in(key_swap, rnd))
        cand = out.winner
        slot = jnp.argmin(out.aux[out.winner_pos]).astype(jnp.int32)
        # exact incumbent verification (one n-vector of distances), with the
        # same relative tolerance the host loop used
        dc = pw(data[cand][None, :], data)[0]
        mine = nearest == slot
        delta = jnp.sum(jnp.where(mine, jnp.minimum(dc, d2) - d1,
                                  jnp.minimum(dc - d1, 0.0)))
        tol = -1e-6 * jnp.maximum(1.0, jnp.sum(d1) / n)
        accept = (delta < tol) & ~done
        reject = (delta >= tol) & ~done
        executed = executed + jnp.where(done, 0, 1)
        rejections = jnp.where(accept, 0, rejections + reject)
        done = done | (rejections >= 2)
        meds = jnp.where(accept, meds.at[slot].set(cand.astype(jnp.int32)),
                         meds)
        dmat = jnp.where(accept, dmat.at[:, slot].set(dc), dmat)
        swaps = swaps + accept
        return (dmat, meds, swaps, rejections, executed, done), None

    carry = (dmat, meds, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
             jnp.zeros((), jnp.int32), jnp.zeros((), bool))
    (dmat, meds, swaps, _, executed, _), _ = jax.lax.scan(
        body, carry, jnp.arange(max_rounds, dtype=jnp.int32))
    d1, _, nearest = _top2_of(dmat)
    return meds, nearest, jnp.sum(d1), swaps, executed


# The (n, k) cache is donated into the sweep where donation is real (the
# caller's copy is dead after the phase); on CPU jax ignores donations with a
# warning, so the flag is folded away there — same program either way.
_swap_sweep = jax.jit(
    _swap_sweep_impl,
    static_argnames=("max_rounds", "k", "budget", "metric", "backend"),
    donate_argnums=(1,) if donation_enabled() else ())


# --------------------------------------------------------------------------
# ragged per-cluster refinement
# --------------------------------------------------------------------------

def make_direct_refiner(*, metric: str, backend: str, budget_per_arm: int,
                        min_bucket: int = DEFAULT_MIN_BUCKET) -> Refiner:
    """The in-process refiner: coalesce the cluster subproblems into
    power-of-two buckets and answer each bucket with ONE
    ``ragged_medoids`` dispatch — heterogeneous cluster sizes share
    the per-bucket compiled programs with every other ragged traffic
    source. Per-bucket key: ``fold_in(key, n_bucket)``. Batch slots are
    padded to the next power of two (dummy length-1 queries), so the number
    of compiled programs stays bounded no matter how cluster counts shift
    between sweeps — the same fixed-slot trick the MedoidServer uses."""
    def refine(arrays: list, key: jax.Array) -> tuple[list, int]:
        plan = plan_buckets([a.shape[0] for a in arrays], min_bucket)
        locals_: list = [None] * len(arrays)
        pulls = 0
        for nb, idxs in plan.items():
            group = [arrays[i] for i in idxs]
            slots = next_pow2(len(group))
            packed, lens = pack_queries(group, min_bucket,
                                        pad_batch_to=slots)
            meds = ragged_medoids(
                packed, lens, jax.random.fold_in(key, nb),
                budget=budget_per_arm * nb, metric=metric, backend=backend,
                min_bucket=min_bucket)
            # honest accounting: padded slots run the schedule too
            pulls += schedule_pulls(nb, budget_per_arm * nb) * slots
            for s, i in enumerate(idxs):
                locals_[i] = int(meds[s])
        return locals_, pulls
    return refine


# --------------------------------------------------------------------------
# the full pipeline
# --------------------------------------------------------------------------

def _kmedoids_impl(data, k: int, key: jax.Array, *, metric: str = "l2",
                   backend: str = "reference",
                   build_budget_per_arm: int = 16,
                   swap_budget_per_arm: int = 16,
                   refine_budget_per_arm: int = 20,
                   refine_sweeps: int = 1, max_swap_rounds: int = 8,
                   min_bucket: int = DEFAULT_MIN_BUCKET,
                   refiner: Optional[Refiner] = None) -> KMedoidsResult:
    """BUILD -> ragged per-cluster refinement -> bandit SWAP.

    ``data (n, d)``; returns a :class:`KMedoidsResult` whose ``medoids`` are
    point indices (slot order fixed by BUILD) and whose pull counters are
    exact scheduled distance-evaluation counts. Keys derive per phase
    (``fold_in(key, 0/1/2)`` for BUILD / refine / SWAP) so any phase is
    reproducible in isolation. ``refiner`` overrides how the per-cluster
    subproblems are answered (default: in-process bucketed ragged
    dispatches; see :class:`repro.cluster.service.ServiceRefiner` for the
    continuous-batching route).
    """
    data = jnp.asarray(data)
    if data.ndim != 2:
        raise ValueError(f"expected (n, d) data, got shape {data.shape}")
    n = int(data.shape[0])
    if not 1 <= k <= n:
        raise ValueError(f"need 1 <= k <= n, got k={k}, n={n}")
    get_backend(backend)                  # fail before any work
    if refiner is None:
        refiner = make_direct_refiner(metric=metric, backend=backend,
                                      budget_per_arm=refine_budget_per_arm,
                                      min_bucket=min_bucket)

    build_budget = build_budget_per_arm * n
    swap_budget = swap_budget_per_arm * n

    # ---------------- BUILD: k correlated-SH argmin steps ----------------
    # Step 0 IS the paper's problem (same cached program as find_medoid);
    # steps 1..k-1 run as ONE device-resident scan program — the d1/chosen
    # caches and every per-step winner stay on device, and the only host
    # sync of the whole phase is reading the final (k,) medoid vector out
    # for the (host-side) refinement bookkeeping below.
    key_build = jax.random.fold_in(key, 0)
    m0 = _medoid_impl(data, jax.random.fold_in(key_build, 0),
                      budget=build_budget, metric=metric, backend=backend)
    if k > 1:
        meds_dev, _ = _build_scan(data, m0, key_build, k=k,
                                  budget=build_budget, metric=metric,
                                  backend=backend)
    else:   # k == 1: an empty scan would still trace the step body, whose
        meds_dev = m0[None].astype(jnp.int32)   # n==1 schedule is empty

    meds: list[int] = [int(m) for m in meds_dev]     # one post-phase sync
    build_pulls = k * (schedule_pulls(n, build_budget) + n)

    dmat, d1, d2, nearest = _assign(data, meds_dev, metric=metric,
                                    backend=backend)
    assign_pulls = n * k

    # ------- ragged per-cluster refinement with affected-set caching -------
    key_refine = jax.random.fold_in(key, 1)
    refine_pulls = refine_updates = 0
    changed = set(range(k))
    for sweep in range(refine_sweeps):
        if not changed:
            break
        labels_np = np.asarray(nearest)
        which = [(c, np.flatnonzero(labels_np == c)) for c in sorted(changed)]
        which = [(c, mem) for c, mem in which if mem.size > 0]
        if not which:
            break
        locals_, p = refiner([data[mem] for _, mem in which],
                             jax.random.fold_in(key_refine, sweep))
        refine_pulls += p
        updates = 0
        for (c, mem), loc in zip(which, locals_):
            g = int(mem[int(loc)])
            if g != meds[c]:
                meds[c] = g
                updates += 1
        refine_updates += updates
        if updates == 0:
            break
        dmat, d1, d2, nearest = _assign(data, jnp.asarray(meds, jnp.int32),
                                        metric=metric, backend=backend)
        assign_pulls += n * k
        moved = np.asarray(nearest) != labels_np
        changed = (set(np.asarray(nearest)[moved].tolist())
                   | set(labels_np[moved].tolist())) if moved.any() else set()

    # ---------------- SWAP: bandit FasterPAM local search ----------------
    # The whole phase is ONE device-resident program (see _swap_sweep): the
    # bandit argmin, the exact incumbent verification, the accept/reject
    # state machine, and the incremental one-column cache updates all run
    # inside a single lax.scan — a round that doesn't verify re-draws
    # references under the next round's key (estimator noise, not
    # convergence) and the sweep latches off after two consecutive
    # rejections, exactly like the host loop it replaces.
    key_swap = jax.random.fold_in(key, 2)
    swap_pulls = swaps = 0
    # k == n leaves no swap-in candidates (every point is a medoid) — and
    # covers n == 1, whose empty round schedule the argmin couldn't handle
    if k < n and max_swap_rounds > 0:
        meds_dev, nearest, cost_dev, swaps_dev, executed = _swap_sweep(
            data, dmat, jnp.asarray(meds, jnp.int32), key_swap,
            max_rounds=max_swap_rounds, k=k, budget=swap_budget,
            metric=metric, backend=backend)
        meds = [int(m) for m in meds_dev]          # one post-phase sync
        swaps = int(swaps_dev)
        swap_pulls = int(executed) * (schedule_pulls(n, swap_budget) + n)
        cost = float(cost_dev)
        labels = np.asarray(nearest)
    else:
        cost = float(jnp.sum(d1))
        labels = np.asarray(nearest)

    pulls = build_pulls + assign_pulls + refine_pulls + swap_pulls
    return KMedoidsResult(
        medoids=meds, labels=labels, cost=cost,
        pulls=pulls, build_pulls=build_pulls, assign_pulls=assign_pulls,
        refine_pulls=refine_pulls, swap_pulls=swap_pulls, swaps=swaps,
        refine_updates=refine_updates, k=k, metric=metric, backend=backend)


def bandit_kmedoids(data, k: int, key: jax.Array, **kwargs) -> KMedoidsResult:
    """Deprecated: use :func:`repro.api.kmedoids` (same pipeline, config-
    driven). Signature-compatible with the pre-facade entry point."""
    warn_once("repro.cluster.kmedoids.bandit_kmedoids", "repro.api.kmedoids")
    return _kmedoids_impl(data, k, key, **kwargs)
