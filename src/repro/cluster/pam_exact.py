"""Exact PAM (BUILD + SWAP) — the k-medoids ground truth.

The reference the bandit subsystem is validated against, in the same spirit
as :mod:`repro.core.exact` for the single-medoid problem: compute the full
``(n, n)`` distance matrix once (that is exactly ``n^2`` distance
evaluations — the pull count every bandit run is compared to), then run

* **BUILD**: greedy seeding — step t adds the point minimizing
  ``sum_j min(d1_j, D[i, j])`` given the nearest-medoid cache ``d1``;
* **SWAP**: FasterPAM-style best-improvement search — for every swap-in
  candidate c the deltas against ALL k medoids come from one pass over the
  matrix row using the cached nearest/second-nearest distances:

      delta(c, i) = sum_j min(D[c,j] - d1_j, 0)                 [shared]
                  + sum_{j: nearest_j = i} [ min(D[c,j], d2_j) - d1_j
                                             - min(D[c,j] - d1_j, 0) ]

  applied until no swap strictly improves the cost.

Everything after the matrix is cache arithmetic, so ``pulls == n * n``
always — :func:`pam_pulls` exposes that count without running anything
(used by tests/benchmarks that only need the comparison baseline at scales
where actually running exact PAM would be wasteful).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core.distances import pairwise


@dataclass
class PAMResult:
    medoids: list[int]            # k point indices, BUILD order preserved
    labels: np.ndarray            # (n,) medoid slot per point
    cost: float                   # sum of distances to assigned medoids
    pulls: int                    # distance evaluations (= n^2, the matrix)
    swaps: int                    # accepted SWAP moves
    build_medoids: list[int] = field(default_factory=list)  # pre-SWAP seeding


def pam_pulls(n: int) -> int:
    """Distance evaluations exact PAM performs: the full matrix, once."""
    return n * n


def distance_matrix(data, metric: str = "l2", block: int = 256) -> np.ndarray:
    """The full (n, n) matrix in row blocks (bounds the ℓ1 broadcast
    intermediate to ``block x n x d``)."""
    data = jnp.asarray(data)
    n = data.shape[0]
    dist = pairwise(metric)
    rows = [np.asarray(dist(data[i:i + block], data))
            for i in range(0, n, block)]
    return np.concatenate(rows, axis=0)


def pam_build(dmat: np.ndarray, k: int) -> tuple[list[int], np.ndarray]:
    """Greedy BUILD on a precomputed matrix: returns (medoids, d1 cache)."""
    n = dmat.shape[0]
    medoids: list[int] = []
    d1 = np.full(n, np.inf)
    for _ in range(k):
        scores = np.minimum(dmat, d1[None, :]).sum(axis=1)
        scores[medoids] = np.inf        # re-picking a medoid gains nothing
        m = int(np.argmin(scores))
        medoids.append(m)
        d1 = np.minimum(d1, dmat[m])
    return medoids, d1


def _caches(dmat: np.ndarray, medoids: list[int]):
    """nearest/second-nearest caches from the medoid columns."""
    cols = dmat[:, medoids]
    order = np.argsort(cols, axis=1, kind="stable")
    nearest = order[:, 0]
    d1 = cols[np.arange(cols.shape[0]), nearest]
    if len(medoids) > 1:
        second = order[:, 1]
        d2 = cols[np.arange(cols.shape[0]), second]
    else:
        d2 = np.full(cols.shape[0], np.inf)
    return nearest.astype(np.int64), d1, d2


def pam_swap(dmat: np.ndarray, medoids: list[int],
             max_rounds: int = 1000) -> tuple[list[int], int]:
    """Best-improvement SWAP until convergence; returns (medoids, swaps)."""
    n = dmat.shape[0]
    k = len(medoids)
    medoids = list(medoids)
    swaps = 0
    for _ in range(max_rounds):
        nearest, d1, d2 = _caches(dmat, medoids)
        gain = np.minimum(dmat - d1[None, :], 0.0)          # (n, n)
        shared = gain.sum(axis=1)                           # (n,)
        term = np.minimum(dmat, d2[None, :]) - d1[None, :] - gain
        onehot = np.eye(k)[nearest]                         # (n, k)
        delta = shared[:, None] + term @ onehot             # (n, k)
        delta[medoids, :] = np.inf                          # medoids can't swap in
        c, i = np.unravel_index(np.argmin(delta), delta.shape)
        if delta[c, i] >= -1e-9 * max(1.0, float(d1.sum())):
            break
        medoids[int(i)] = int(c)
        swaps += 1
    return medoids, swaps


def pam_exact(data, k: int, metric: str = "l2",
              max_swap_rounds: int = 1000) -> PAMResult:
    """Full exact PAM: BUILD + SWAP-to-convergence on the (n, n) matrix."""
    dmat = distance_matrix(data, metric)
    n = dmat.shape[0]
    if not 1 <= k <= n:
        raise ValueError(f"need 1 <= k <= n, got k={k}, n={n}")
    build_meds, _ = pam_build(dmat, k)
    medoids, swaps = pam_swap(dmat, build_meds, max_rounds=max_swap_rounds)
    nearest, d1, _ = _caches(dmat, medoids)
    return PAMResult(medoids=medoids, labels=nearest, cost=float(d1.sum()),
                     pulls=pam_pulls(n), swaps=swaps,
                     build_medoids=build_meds)
