"""Multi-head Latent Attention (DeepSeek-V2), TPU-adapted.

Prefill/train path expands the compressed latent to full per-head K/V and runs
the shared blockwise flash attention (value head dim 128 != qk head dim 192 is
supported). Decode path uses the *absorbed* formulation: the k up-projection is
folded into the query and the v up-projection into the output, so the per-token
cache is just (kv_lora_rank + rope_head_dim) = 576 floats — the paper-accurate
MLA memory win (vs 2*H*128 = 4096 for vanilla GQA kv=16).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MLACfg
from repro.models import layers as L
from repro.models.attention import flash_attention
from repro.models.sharding import constrain

NEG_INF = -1e30


def mla_init(key, d_model: int, num_heads: int, cfg: MLACfg, dtype):
    ks = jax.random.split(key, 7)
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    return {
        # queries: full-rank (v2-lite has no q compression)
        "wq": L.dense_init(ks[0], d_model, num_heads * (dn + dr), dtype),
        # kv path: compress, plus shared rope key
        "w_dkv": L.dense_init(ks[1], d_model, r, dtype),
        "w_krope": L.dense_init(ks[2], d_model, dr, dtype),
        "kv_norm": L.rmsnorm_init(r),
        "w_uk": L.dense_init(ks[3], r, num_heads * dn, dtype),
        "w_uv": L.dense_init(ks[4], r, num_heads * dv, dtype),
        "wo": L.dense_init(ks[5], num_heads * dv, d_model, dtype),
    }


def _split_q(params, x, num_heads, cfg: MLACfg):
    B, S, _ = x.shape
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    q = (x @ params["wq"]).reshape(B, S, num_heads, dn + dr)
    return q[..., :dn], q[..., dn:]


def _latent(params, x):
    c_kv = L.rmsnorm(params["kv_norm"], x @ params["w_dkv"])
    k_rope = x @ params["w_krope"]                     # (B, S, dr) shared head
    return c_kv, k_rope


def mla_prefill(params, x, *, num_heads, cfg: MLACfg, theta,
                q_offset: int = 0, differentiable: bool = False):
    """Returns (out, (c_kv, k_rope)) — the compressed cache."""
    B, S, _ = x.shape
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    pos = q_offset + jnp.arange(S)[None, :]

    q_nope, q_rope = _split_q(params, x, num_heads, cfg)
    q_rope = L.apply_rope(q_rope, pos, theta)
    c_kv, k_rope = _latent(params, x)
    k_rope = L.apply_rope(k_rope[:, :, None, :], pos, theta)   # (B,S,1,dr)

    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, num_heads, dn)
    v = (c_kv @ params["w_uv"]).reshape(B, S, num_heads, dv)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (B, S, num_heads, dr))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    q = constrain(q, "batch", None, "model", None)
    out = flash_attention(q, k, v, causal=True, q_offset=q_offset,
                          scale=1.0 / math.sqrt(dn + dr),
                          differentiable=differentiable)
    out = out.reshape(B, S, num_heads * dv) @ params["wo"]
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(params, x, cache_ckv, cache_krope, pos, *, num_heads,
               cfg: MLACfg, theta):
    """Absorbed decode. x: (B, 1, d); caches (B, S_max, r) and (B, S_max, dr)."""
    B = x.shape[0]
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B, 1))

    q_nope, q_rope = _split_q(params, x, num_heads, cfg)       # (B,1,H,*)
    q_rope = L.apply_rope(q_rope, posv, theta)
    c_kv, k_rope = _latent(params, x)                          # (B,1,r),(B,1,dr)
    k_rope = L.apply_rope(k_rope[:, :, None, :], posv, theta)[:, :, 0, :]

    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv.astype(cache_ckv.dtype), pos, axis=1)
    cache_krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, k_rope.astype(cache_krope.dtype), pos, axis=1)

    # absorb W_uk into the query: q_c (B, H, r)
    w_uk = params["w_uk"].reshape(r, num_heads, dn)
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    scale = 1.0 / math.sqrt(dn + dr)
    s = (jnp.einsum("bhr,bsr->bhs", q_c, cache_ckv.astype(jnp.float32))
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                      cache_krope.astype(jnp.float32))) * scale
    Smax = cache_ckv.shape[1]
    mask = jnp.arange(Smax)[None, :] <= jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32), (B,))[:, None]
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", p, cache_ckv.astype(jnp.float32))
    # absorb W_uv into the output: per-head (r -> dv)
    w_uv = params["w_uv"].reshape(r, num_heads, dv)
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, num_heads * dv).astype(x.dtype) @ params["wo"]
    return out, cache_ckv, cache_krope
