"""Unified Model interface: init / loss / prefill / decode for every family.

``build_model(cfg)`` returns a ``Model`` whose members are pure functions —
the launcher jits/shards them; tests call them eagerly. Batches are dicts:

  {"tokens": (B, S) int32}                              LM families
  {"tokens", "frames": (B, S_enc, d_model)}             audio (conv stub)
  {"tokens", "image_embed": (B, N_img, d_model)}        vlm (patch stub)

Loss is next-token cross entropy (decoder tokens for enc-dec) + MoE aux.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.models import encdec as ED
from repro.models import recurrent as R
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelCfg
    init: Callable[[jax.Array], Any]
    loss: Callable[..., Any]            # (params, batch, remat=) -> (loss, metrics)
    prefill: Callable[..., Any]         # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable[..., Any]     # (params, token, cache, pos, batch=) -> (logits, cache)
    init_cache: Callable[..., Any]      # (batch_size, max_len) -> cache


def _xent(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token CE on explicit logits (small-vocab / test path)."""
    from repro.models.sharding import constrain
    logits = constrain(logits.astype(jnp.float32), "batch", None, "vocab")
    targets = tokens[:, 1:]
    lg = logits[:, :-1]
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    onehot = constrain(onehot, "batch", None, "vocab")
    target_logit = jnp.einsum("bsv,bsv->bs", lg, onehot)
    lse = jax.nn.logsumexp(lg, axis=-1)
    return jnp.mean(lse - target_logit)


def fused_xent(x: jnp.ndarray, tokens: jnp.ndarray, head: jnp.ndarray,
               chunk: int = 256) -> jnp.ndarray:
    """Fused unembed + next-token CE, chunked over the sequence.

    ``x``: final hidden states (B, S, d); ``head``: (V, d) unembedding.
    Logits exist only per (B, chunk, V) block, rematerialized in the
    backward pass — the full (B, S, V) f32 tensor (4+ GB/chip on 256k-vocab
    configs, the dominant live buffer in early dry-runs) never exists.
    """
    from repro.models.sharding import _rules, constrain
    B, S, d = x.shape
    rules = _rules()
    if rules is not None and rules.get("vocab") is None:
        # no mesh axis left for the vocab dim (pure-FSDP cells where batch
        # occupies every axis): the chunked scan's remat re-gathers the
        # FSDP-sharded head every chunk (measured: dominant collective), so
        # one full (B_loc, S, V) logits block is cheaper here.
        logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                            head.astype(jnp.float32))
        return _xent(logits, tokens)
    # gather the unembedding ONCE (vs per chunk inside the scan)
    head = constrain(head, None, "vocab")
    xs = x[:, :-1]
    targets = tokens[:, 1:]
    n = S - 1
    c = min(chunk, n)
    pad = (-n) % c
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nc = (n + pad) // c
    xs = xs.reshape(B, nc, c, d).transpose(1, 0, 2, 3)
    tg = targets.reshape(B, nc, c).transpose(1, 0, 2)
    valid = (jnp.arange(nc * c) < n).reshape(nc, c)

    @jax.checkpoint
    def body(acc, inp):
        xc, tc, vc = inp                               # (B,c,d),(B,c),(c,)
        logits = jnp.einsum("bcd,vd->bcv", xc.astype(jnp.float32),
                            head.astype(jnp.float32))
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)        # (B,c)
        onehot = jax.nn.one_hot(tc, logits.shape[-1], dtype=jnp.float32)
        onehot = constrain(onehot, "batch", None, "vocab")
        tl = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return acc + jnp.sum((lse - tl) * vc[None, :]), 0

    acc, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, tg, valid))
    return acc / (B * n)


def build_model(cfg: ModelCfg) -> Model:
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def init(key):
            return T.transformer_init(key, cfg)

        def loss(params, batch, remat: bool = True):
            x, aux, _ = T.transformer_forward(
                params, cfg, batch["tokens"],
                image_embed=batch.get("image_embed"), remat=remat,
                return_hidden=True)
            l = fused_xent(x, batch["tokens"], T.head_matrix(params, cfg))
            l = l + 0.01 * aux
            return l, {"xent": l, "moe_aux": aux}

        def prefill(params, batch, max_len):
            return T.transformer_prefill(params, cfg, batch["tokens"], max_len,
                                         image_embed=batch.get("image_embed"))

        def decode_step(params, token, cache, pos, batch=None):
            img = None if batch is None else batch.get("image_embed")
            return T.transformer_decode_step(params, cfg, token, cache, pos,
                                             image_embed=img)

        def init_cache(batch_size, max_len):
            return T.init_kv_cache(cfg, batch_size, max_len)

    elif fam == "ssm":   # xLSTM
        def init(key):
            return R.xlstm_init(key, cfg)

        def loss(params, batch, remat: bool = True):
            x, _ = R.xlstm_forward(params, cfg, batch["tokens"], remat=remat,
                                   return_hidden=True)
            l = fused_xent(x, batch["tokens"], R.head_matrix(params, cfg))
            return l, {"xent": l}

        def prefill(params, batch, max_len):
            return R.xlstm_prefill(params, cfg, batch["tokens"], max_len)

        def decode_step(params, token, cache, pos, batch=None):
            return R.xlstm_decode_step(params, cfg, token, cache, pos)

        def init_cache(batch_size, max_len):
            return R.xlstm_init_cache(cfg, batch_size)

    elif fam == "hybrid":  # zamba2
        def init(key):
            return R.hybrid_init(key, cfg)

        def loss(params, batch, remat: bool = True):
            x, _ = R.hybrid_forward(params, cfg, batch["tokens"], remat=remat,
                                    return_hidden=True)
            l = fused_xent(x, batch["tokens"], R.head_matrix(params, cfg))
            return l, {"xent": l}

        def prefill(params, batch, max_len):
            return R.hybrid_prefill(params, cfg, batch["tokens"], max_len)

        def decode_step(params, token, cache, pos, batch=None):
            return R.hybrid_decode_step(params, cfg, token, cache, pos)

        def init_cache(batch_size, max_len):
            return R.hybrid_init_cache(cfg, batch_size, max_len)

    elif fam == "audio":  # whisper
        def init(key):
            return ED.encdec_init(key, cfg)

        def loss(params, batch, remat: bool = True):
            enc_out = ED.encode(params, cfg, batch["frames"],
                                differentiable=True)
            x, _ = ED.decode_train(params, cfg, batch["tokens"], enc_out,
                                   remat=remat, return_hidden=True)
            l = fused_xent(x, batch["tokens"], params["embed"])
            return l, {"xent": l}

        def prefill(params, batch, max_len):
            return ED.encdec_prefill(params, cfg, batch["tokens"],
                                     batch["frames"], max_len)

        def decode_step(params, token, cache, pos, batch=None):
            return ED.encdec_decode_step(params, cfg, token, cache, pos)

        def init_cache(batch_size, max_len):
            return ED.encdec_init_cache(cfg, batch_size, max_len)

    else:
        raise ValueError(f"unknown family {fam!r}")

    return Model(cfg=cfg, init=init, loss=loss, prefill=prefill,
                 decode_step=decode_step, init_cache=init_cache)
