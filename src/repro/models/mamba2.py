"""Mamba2 (SSD) block, TPU-adapted.

Training/prefill uses the chunked State-Space-Dual algorithm: the sequence is
split into chunks of length C; within a chunk the recurrence is computed as a
masked (attention-like) matmul — MXU work — and states are passed between
chunks with a lax.scan (S/C serial steps instead of S). This is the TPU-native
re-think of the CUDA selective-scan kernel: we trade the GPU's in-register
sequential scan for systolic-array matmuls + a short scan, which is how the
memory hierarchy (HBM->VMEM->MXU) wants it.

Decode is the O(1) recurrence h <- a h + dt * B x per step, plus a rolling
causal-conv window.

Shapes: d_inner = expand * d_model, heads = d_inner / head_dim (P = head_dim),
scalar decay per head (A), B/C shared across heads (ngroups = 1), state N.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMCfg
from repro.models import layers as L


class Mamba2State(NamedTuple):
    h: jnp.ndarray        # (B, H, P, N) SSM state
    conv: jnp.ndarray     # (B, d_conv-1, conv_dim) rolling conv input window


def _dims(d_model: int, cfg: SSMCfg):
    d_inner = cfg.expand * d_model
    heads = d_inner // cfg.head_dim
    conv_dim = d_inner + 2 * cfg.d_state    # x, B, C all pass the conv
    return d_inner, heads, conv_dim


def mamba2_init(key, d_model: int, cfg: SSMCfg, dtype):
    d_inner, heads, conv_dim = _dims(d_model, cfg)
    ks = jax.random.split(key, 5)
    # in_proj -> [z (gate), x, B, C, dt]
    d_proj = 2 * d_inner + 2 * cfg.d_state + heads
    return {
        "w_in": L.dense_init(ks[0], d_model, d_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((heads,), jnp.float32),       # A = -exp(A_log)
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "w_out": L.dense_init(ks[2], d_inner, d_model, dtype),
        "norm": L.rmsnorm_init(d_inner),
    }


def _split_proj(proj, d_inner, d_state, heads):
    z, xBC_dt = jnp.split(proj, [d_inner], axis=-1)
    xBC, dt = jnp.split(xBC_dt, [d_inner + 2 * d_state], axis=-1)
    return z, xBC, dt                                     # dt: (..., heads)


def _causal_conv(xBC, w, b):
    """xBC: (B, S, conv_dim); depthwise causal conv, kernel K."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def mamba2_apply(params, x, cfg: SSMCfg, *, return_state: bool = False):
    """x: (B, S, d) -> y (B, S, d) [, final Mamba2State]."""
    B, S, d_model = x.shape
    d_inner, heads, conv_dim = _dims(d_model, cfg)
    N, P, C = cfg.d_state, cfg.head_dim, min(cfg.chunk, S)

    proj = x @ params["w_in"]
    z, xBC, dt = _split_proj(proj, d_inner, N, heads)
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    A = -jnp.exp(params["A_log"])                                      # (H,)
    xh = xs.reshape(B, S, heads, P).astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)                                        # (B,S,N)
    Cm = Cm.astype(jnp.float32)

    pad = (-S) % C
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // C

    # chunked layout: (nc, B, C, ...)
    xc = xh.reshape(B, nc, C, heads, P).transpose(1, 0, 2, 3, 4)
    Bc = Bm.reshape(B, nc, C, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(B, nc, C, N).transpose(1, 0, 2, 3)
    dtc = dt.reshape(B, nc, C, heads).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        xk, Bk, Ck, dtk = inp          # (B,C,H,P), (B,C,N), (B,C,N), (B,C,H)
        la = dtk * A                   # log decay per step (B,C,H)
        cum = jnp.cumsum(la, axis=1)   # (B,C,H)
        # intra-chunk: M[t,s] = (C_t . B_s) exp(cum_t - cum_s) dt_s, s <= t
        gram = jnp.einsum("btn,bsn->bts", Ck, Bk)                  # (B,C,C)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (B,C,C,H)
        tri = jnp.tril(jnp.ones((C, C), bool))
        M = jnp.where(tri[None, :, :, None], gram[..., None] * decay, 0.0)
        M = M * dtk[:, None, :, :]                                 # weight dt_s
        y = jnp.einsum("btsh,bshp->bthp", M, xk)
        # inter-chunk: contribution of incoming state
        y = y + jnp.einsum("btn,bhnp,bth->bthp", Ck, h.transpose(0, 1, 3, 2),
                           jnp.exp(cum))
        # state update: h' = exp(sum la) h + sum_s exp(cum_C - cum_s) dt_s B_s x_s^T
        tail = jnp.exp(cum[:, -1:, :] - cum)                       # (B,C,H)
        dB = Bk[:, :, None, :] * (dtk * tail)[..., None]           # (B,C,H,N)
        h_new = jnp.exp(cum[:, -1, :])[:, :, None, None] * h \
            + jnp.einsum("bchn,bchp->bhpn", dB, xk)
        return h_new, y

    h0 = jnp.zeros((B, heads, P, N), jnp.float32)
    h_final, yc = jax.lax.scan(chunk_step, h0, (xc, Bc, Cc, dtc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, nc * C, heads, P)[:, :S]
    y = y + xh[:, :S] * params["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = L.rmsnorm(params["norm"], y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["w_out"]
    if return_state:
        K = params["conv_w"].shape[0]
        pre_conv = jnp.concatenate(
            [jnp.zeros((B, max(K - 1 - S, 0), conv_dim), x.dtype),
             _pre_conv_tail(x, params, d_inner, N, K, S)], axis=1)
        return out, Mamba2State(h=h_final, conv=pre_conv)
    return out


def _pre_conv_tail(x, params, d_inner, N, K, S):
    """Last K-1 pre-conv xBC inputs (for decode continuation)."""
    proj = x[:, max(0, S - (K - 1)):, :] @ params["w_in"]
    _, xBC, _ = _split_proj(proj, d_inner, N, params["dt_bias"].shape[0])
    return xBC.astype(x.dtype)


def mamba2_init_state(params, batch: int, d_model: int, cfg: SSMCfg, dtype):
    d_inner, heads, conv_dim = _dims(d_model, cfg)
    return Mamba2State(
        h=jnp.zeros((batch, heads, cfg.head_dim, cfg.d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
    )


def mamba2_decode(params, x, state: Mamba2State, cfg: SSMCfg
                  ) -> Tuple[jnp.ndarray, Mamba2State]:
    """x: (B, 1, d) single-token step."""
    B, _, d_model = x.shape
    d_inner, heads, conv_dim = _dims(d_model, cfg)
    N, P = cfg.d_state, cfg.head_dim
    K = cfg.d_conv

    proj = x @ params["w_in"]                             # (B,1,*)
    z, xBC, dt = _split_proj(proj, d_inner, N, heads)
    window = jnp.concatenate([state.conv, xBC], axis=1)   # (B, K, conv_dim)
    conv_out = jnp.sum(window * params["conv_w"][None], axis=1) + params["conv_b"]
    xBC1 = jax.nn.silu(conv_out)                          # (B, conv_dim)
    xs, Bm, Cm = jnp.split(xBC1, [d_inner, d_inner + N], axis=-1)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt1 * A)                                  # (B,H)
    xh = xs.reshape(B, heads, P).astype(jnp.float32)
    h = a[:, :, None, None] * state.h + jnp.einsum(
        "bh,bhp,bn->bhpn", dt1, xh, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(B, 1, d_inner)
    y = L.rmsnorm(params["norm"], y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["w_out"]
    return out, Mamba2State(h=h, conv=window[:, 1:])
