"""Flash attention with a custom VJP — the training-path attention kernel.

Plain autodiff of a blockwise-softmax scan defeats the whole point: JAX saves
every per-block probability matrix for the backward pass, reconstructing the
O(S^2) memory footprint (measured: 50+ GB/device on a 4k whisper train step).
This module implements the standard flash backward (Dao et al., adapted to
XLA/TPU): forward saves only (q, k, v, out, L = m + log l); backward
recomputes each block's probabilities on the fly and accumulates dq / dk / dv
block-by-block — activation memory O(S * Dh), never O(S^2).

GQA layout matches attention.py: q (B, Sq, H, Dh); k, v (B, Skv, KV, Dh);
supports causal masking and a (possibly traced) sliding window.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, skv, causal, window_t):
    mask = (k_pos[None, :] < skv)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    wmask = (q_pos[:, None] - k_pos[None, :]) < window_t
    return mask & jnp.where(window_t > 0, wmask, True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def flash_train(q, k, v, window, causal: bool, q_offset: int,
                bq: int, bkv: int, scale: float, skv_true: int):
    """q: (B,Sq,H,Dh); returns (B,Sq,H,Dv) in q.dtype."""
    out, _ = _flash_fwd_impl(q, k, v, window, causal, q_offset, bq, bkv,
                             scale, skv_true)
    return out


def _flash_fwd_impl(q, k, v, window, causal, q_offset, bq, bkv, scale,
                    skv_true):
    B, Sq, H, Dh = q.shape
    _, Skv, KV, _ = k.shape
    Dv = v.shape[-1]
    rep = H // KV
    nq, nkv = Sq // bq, Skv // bkv
    window_t = jnp.asarray(window, jnp.int32)

    qf = q.astype(jnp.float32) * scale
    qb = qf.reshape(B, nq, bq, KV, rep, Dh).transpose(1, 0, 2, 3, 4, 5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def q_block(carry, inp):
        qblk, qi = inp
        q_pos = q_offset + qi * bq + jnp.arange(bq)

        def body(t, st):
            m, l, acc = st
            kblk = jax.lax.dynamic_slice_in_dim(kf, t * bkv, bkv, 1)
            vblk = jax.lax.dynamic_slice_in_dim(vf, t * bkv, bkv, 1)
            s = jnp.einsum("bqkrd,bjkd->bkrqj", qblk, kblk)
            k_pos = t * bkv + jnp.arange(bkv)
            mask = _block_mask(q_pos, k_pos, skv_true, causal, window_t)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask.astype(jnp.float32)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkrqj,bjkd->bkrqd", p, vblk)
            return m_new, l_new, acc_new

        m0 = jnp.full((B, KV, rep, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, bq, Dv), jnp.float32)
        m, l, acc = jax.lax.fori_loop(0, nkv, body, (m0, l0, a0))
        lsafe = jnp.maximum(l, 1e-30)
        out = (acc / lsafe[..., None]).transpose(0, 3, 1, 2, 4)  # (B,bq,KV,rep,Dv)
        lse = m + jnp.log(lsafe)                                  # (B,KV,rep,bq)
        return carry, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_block, 0, (qb, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * bq, H, Dv)
    lse = lses                                                    # (nq,B,KV,rep,bq)
    return out, lse


def _flash_fwd(q, k, v, window, causal, q_offset, bq, bkv, scale, skv_true):
    out, lse = _flash_fwd_impl(q, k, v, window, causal, q_offset, bq, bkv,
                               scale, skv_true)
    return out, (q, k, v, window, out, lse)


def _flash_bwd(causal, q_offset, bq, bkv, scale, skv_true, res, dout):
    q, k, v, window, out, lse = res
    B, Sq, H, Dh = q.shape
    _, Skv, KV, _ = k.shape
    Dv = v.shape[-1]
    rep = H // KV
    nq, nkv = Sq // bq, Skv // bkv
    window_t = jnp.asarray(window, jnp.int32)

    qf = (q.astype(jnp.float32) * scale).reshape(
        B, nq, bq, KV, rep, Dh).transpose(1, 0, 2, 3, 4, 5)   # (nq,B,bq,KV,rep,Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    do = dout.astype(jnp.float32).reshape(
        B, nq, bq, KV, rep, Dv).transpose(1, 0, 2, 3, 4, 5)
    ob = out.astype(jnp.float32).reshape(
        B, nq, bq, KV, rep, Dv).transpose(1, 0, 2, 3, 4, 5)
    # delta[row] = sum_d dout * out   (B,KV,rep,bq) per q block
    delta = jnp.einsum("nbqkrd,nbqkrd->nbkrq", do, ob)

    def q_block(carry, inp):
        dk_tot, dv_tot = carry
        qblk, doblk, lseblk, dblk, qi = inp
        q_pos = q_offset + qi * bq + jnp.arange(bq)

        def body(t, st):
            dq_acc, dk_acc, dv_acc = st
            kblk = jax.lax.dynamic_slice_in_dim(kf, t * bkv, bkv, 1)
            vblk = jax.lax.dynamic_slice_in_dim(vf, t * bkv, bkv, 1)
            s = jnp.einsum("bqkrd,bjkd->bkrqj", qblk, kblk)
            k_pos = t * bkv + jnp.arange(bkv)
            mask = _block_mask(q_pos, k_pos, skv_true, causal, window_t)
            s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lseblk[..., None]) * mask.astype(jnp.float32)
            dv_blk = jnp.einsum("bkrqj,bqkrd->bjkd", p, doblk)
            dp = jnp.einsum("bqkrd,bjkd->bkrqj", doblk, vblk)
            ds = p * (dp - dblk[..., None])                    # (B,KV,rep,bq,bkv)
            dq_acc = dq_acc + jnp.einsum("bkrqj,bjkd->bqkrd", ds, kblk)
            dk_blk = jnp.einsum("bkrqj,bqkrd->bjkd", ds, qblk)
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, dk_blk + jax.lax.dynamic_slice_in_dim(
                    dk_acc, t * bkv, bkv, 1), t * bkv, axis=1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, dv_blk + jax.lax.dynamic_slice_in_dim(
                    dv_acc, t * bkv, bkv, 1), t * bkv, axis=1)
            return dq_acc, dk_acc, dv_acc

        dq0 = jnp.zeros((B, bq, KV, rep, Dh), jnp.float32)
        dk0 = jnp.zeros((B, Skv, KV, Dh), jnp.float32)
        dv0 = jnp.zeros((B, Skv, KV, Dv), jnp.float32)
        dq_b, dk_b, dv_b = jax.lax.fori_loop(0, nkv, body, (dq0, dk0, dv0))
        return (dk_tot + dk_b, dv_tot + dv_b), dq_b

    dk0 = jnp.zeros((B, Skv, KV, Dh), jnp.float32)
    dv0 = jnp.zeros((B, Skv, KV, Dv), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        q_block, (dk0, dv0), (qf, do, lse, delta, jnp.arange(nq)))
    dq = (dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, Dh)
          * scale).astype(q.dtype)
    dk = dk.astype(k.dtype)
    dv = dv.astype(v.dtype)
    dwindow = np.zeros((), dtype=jax.dtypes.float0) if jnp.issubdtype(
        jnp.asarray(window).dtype, jnp.integer) else jnp.zeros_like(window)
    return dq, dk, dv, dwindow


flash_train.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_trainable(q, k, v, *, causal: bool = True, window=0,
                              q_offset: int = 0, block_q: int = 512,
                              block_kv: int = 1024,
                              scale: Optional[float] = None) -> jnp.ndarray:
    """Padding + dispatch wrapper; drop-in for attention.flash_attention in
    the training path. Returns same dtype as q."""
    B, Sq, H, Dh = q.shape
    _, Skv, KV, _ = k.shape
    scale = scale or (1.0 / math.sqrt(Dh))
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    pq, pkv = (-Sq) % bq, (-Skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    out = flash_train(q, k, v, jnp.asarray(window, jnp.int32), causal,
                      q_offset, bq, bkv, scale, Skv)
    return out[:, :Sq].astype(q.dtype)
