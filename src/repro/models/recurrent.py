"""Recurrent-family model assemblies: xLSTM and Zamba2-style hybrid.

xLSTM: layers grouped into super-blocks of (R mLSTM + 1 sLSTM) (7:1 for the
1.3b config), scanned over super-blocks with an inner scan over the mLSTM
stack. sLSTM is serial over time by construction (see xlstm.py).

Zamba2 hybrid: G groups of E Mamba2 blocks with ONE shared full-attention
block applied after every group (same parameters every application, each
application with its own KV cache) — the Zamba weight-sharing trick. The
shared block's params live outside the scanned stack; the per-group KV caches
carry a leading group axis.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import xlstm as XL
from repro.models.sharding import constrain


def _dtype(cfg: ModelCfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _head(params, cfg, x):
    x = L.rmsnorm(params["ln_f"], x)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = (x @ params["lm_head"]).astype(jnp.float32)
    return constrain(logits, "batch", None, "vocab")


def head_matrix(params, cfg):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"].T


# ================================ xLSTM ====================================

def _xlstm_layout(cfg: ModelCfg) -> Tuple[int, int]:
    """(groups, mlstm_per_group): pattern tiles (mlstm * R, slstm)."""
    pat = cfg.block_pattern or ("mlstm",) * 7 + ("slstm",)
    per = len(pat)
    assert cfg.num_layers % per == 0, "num_layers must tile the block pattern"
    r = sum(1 for b in pat if b == "mlstm")
    assert pat == ("mlstm",) * r + ("slstm",) * (per - r), \
        "xlstm pattern must be mlstm-runs then slstm"
    return cfg.num_layers // per, r


def xlstm_init(key, cfg: ModelCfg):
    dt = _dtype(cfg)
    G, R = _xlstm_layout(cfg)
    ks = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "ln_f": L.rmsnorm_init(cfg.d_model),
        "groups": {
            "mlstm": jax.vmap(lambda k: jax.vmap(
                lambda kk: XL.mlstm_init(kk, cfg.d_model, cfg.num_heads, dt))(
                jax.random.split(k, R)))(jax.random.split(ks[1], G)),
            "mln": jax.vmap(lambda k: jax.vmap(
                lambda kk: L.rmsnorm_init(cfg.d_model))(
                jax.random.split(k, R)))(jax.random.split(ks[1], G)),
            "slstm": jax.vmap(lambda k: XL.slstm_init(
                k, cfg.d_model, cfg.num_heads, dt))(jax.random.split(ks[2], G)),
            "sln": jax.vmap(lambda k: L.rmsnorm_init(cfg.d_model))(
                jax.random.split(ks[2], G)),
        },
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[3], cfg.d_model, cfg.vocab_size, dt)
    return params


def xlstm_forward(params, cfg: ModelCfg, tokens, remat: bool = False,
                  collect_state: bool = False, return_hidden: bool = False):
    x = params["embed"][tokens]
    x = constrain(x, "batch", None, None)

    def group_body(x, g):
        def m_body(x, pm):
            pl, ln = pm
            if collect_state:
                out, st = XL.mlstm_apply(pl, L.rmsnorm(ln, x), cfg.num_heads,
                                         return_state=True)
                return x + out, st
            return x + XL.mlstm_apply(pl, L.rmsnorm(ln, x), cfg.num_heads), 0.0

        body = jax.checkpoint(m_body) if remat else m_body
        x, m_states = jax.lax.scan(body, x, (g["mlstm"], g["mln"]))
        # sLSTM needs its final state too; slstm_apply returns outputs only —
        # recompute final state cheaply in collect mode via one decode pass is
        # wasteful, so slstm_apply exposes outputs; state collected via scan
        # inside slstm itself when needed.
        if collect_state:
            out, s_state = _slstm_apply_with_state(g["slstm"], x, cfg.num_heads,
                                                   g["sln"])
            return x + out, (m_states, s_state)
        x = x + XL.slstm_apply(g["slstm"], L.rmsnorm(g["sln"], x), cfg.num_heads)
        return x, 0.0

    gbody = jax.checkpoint(group_body) if (remat and not collect_state) else group_body
    x, states = jax.lax.scan(gbody, x, params["groups"])
    if return_hidden:
        x = L.rmsnorm(params["ln_f"], x)
        return x, (states if collect_state else None)
    return _head(params, cfg, x), (states if collect_state else None)


def _slstm_apply_with_state(p, x, num_heads, ln):
    xh = L.rmsnorm(ln, x)
    B, S, _ = x.shape
    d_inner = p["w_in"].shape[1] // 4
    xin = (xh @ p["w_in"]).astype(jnp.float32)

    def step(st, xt):
        gates = XL._slstm_gates(p, xt, st.h, num_heads, d_inner)
        st = XL._slstm_cell(gates, st, d_inner)
        return st, st.h

    st0 = XL.SLSTMState(
        c=jnp.zeros((B, d_inner), jnp.float32),
        n=jnp.zeros((B, d_inner), jnp.float32),
        h=jnp.zeros((B, d_inner), jnp.float32),
        m=jnp.full((B, d_inner), XL.NEG_INF, jnp.float32))
    st, hs = jax.lax.scan(step, st0, xin.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y) @ p["w_down"]
    return y, st


def xlstm_init_cache(cfg: ModelCfg, batch: int):
    G, R = _xlstm_layout(cfg)
    m = XL.mlstm_init_state(batch, cfg.d_model, cfg.num_heads)
    s = XL.slstm_init_state(batch, cfg.d_model, cfg.num_heads)
    tile_m = jax.tree.map(lambda a: jnp.broadcast_to(a, (G, R) + a.shape), m)
    tile_s = jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), s)
    return {"mlstm": tile_m, "slstm": tile_s}


def xlstm_decode_step(params, cfg: ModelCfg, token, cache, pos=None):
    x = params["embed"][token][:, None, :]

    def group_body(x, g):
        pg, mst, sst = g

        def m_body(x, xs):
            pl, ln, st = xs
            out, st_n = XL.mlstm_decode(pl, L.rmsnorm(ln, x), st, cfg.num_heads)
            return x + out, st_n

        x, mst_n = jax.lax.scan(m_body, x, (pg["mlstm"], pg["mln"], mst))
        out, sst_n = XL.slstm_decode(pg["slstm"], L.rmsnorm(pg["sln"], x), sst,
                                     cfg.num_heads)
        return x + out, (mst_n, sst_n)

    x, (mst, sst) = jax.lax.scan(
        group_body, x, (params["groups"], cache["mlstm"], cache["slstm"]))
    logits = _head(params, cfg, x)
    return logits[:, 0], {"mlstm": mst, "slstm": sst}


def xlstm_prefill(params, cfg: ModelCfg, tokens, max_len: int = 0):
    logits, states = xlstm_forward(params, cfg, tokens, collect_state=True)
    mst, sst = states
    return logits[:, -1], {"mlstm": mst, "slstm": sst}


# ============================ Zamba2 hybrid ================================

def _hybrid_layout(cfg: ModelCfg) -> Tuple[int, int]:
    e = cfg.shared_attn_every or 6
    assert cfg.num_layers % e == 0
    return cfg.num_layers // e, e           # (groups, mamba per group)


def hybrid_init(key, cfg: ModelCfg):
    dt = _dtype(cfg)
    G, E = _hybrid_layout(cfg)
    ks = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "ln_f": L.rmsnorm_init(cfg.d_model),
        "mamba": jax.vmap(lambda k: jax.vmap(
            lambda kk: M2.mamba2_init(kk, cfg.d_model, cfg.ssm, dt))(
            jax.random.split(k, E)))(jax.random.split(ks[1], G)),
        "mln": jax.vmap(lambda k: jax.vmap(lambda kk: L.rmsnorm_init(cfg.d_model))(
            jax.random.split(k, E)))(jax.random.split(ks[1], G)),
        # ONE shared attention block (Zamba trick): params reused at each of
        # the G application points, each with its own KV cache.
        "shared_attn": {
            "ln1": L.rmsnorm_init(cfg.d_model),
            "attn": A.attn_init(ks[2], cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, cfg.resolved_head_dim, dt),
            "ln2": L.rmsnorm_init(cfg.d_model),
            "mlp": L.mlp_init(ks[3], cfg.d_model, cfg.d_ff, dt),
        },
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[4], cfg.d_model, cfg.vocab_size, dt)
    return params


def hybrid_forward(params, cfg: ModelCfg, tokens, remat: bool = False,
                   collect_cache: bool = False, return_hidden: bool = False):
    x = params["embed"][tokens]
    x = constrain(x, "batch", None, None)
    sh = params["shared_attn"]

    def group_body(x, g):
        pm, lns = g

        def m_body(x, xs):
            pl, ln = xs
            if collect_cache:
                out, st = M2.mamba2_apply(pl, L.rmsnorm(ln, x), cfg.ssm,
                                          return_state=True)
                return x + out, st
            return x + M2.mamba2_apply(pl, L.rmsnorm(ln, x), cfg.ssm), 0.0

        body = jax.checkpoint(m_body) if remat else m_body
        x, m_states = jax.lax.scan(body, x, (pm, lns))
        h = L.rmsnorm(sh["ln1"], x)
        attn_out, kv = A.self_attn_apply(
            sh["attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            theta=cfg.rope_theta, window=0, differentiable=not collect_cache)
        x = x + attn_out
        x = x + L.mlp_apply(sh["mlp"], L.rmsnorm(sh["ln2"], x))
        return x, (m_states, kv if collect_cache else 0.0)

    gbody = jax.checkpoint(group_body) if (remat and not collect_cache) else group_body
    x, aux = jax.lax.scan(gbody, x, (params["mamba"], params["mln"]))
    if return_hidden:
        x = L.rmsnorm(params["ln_f"], x)
        return x, (aux if collect_cache else None)
    return _head(params, cfg, x), (aux if collect_cache else None)


def hybrid_init_cache(cfg: ModelCfg, batch: int, max_len: int):
    G, E = _hybrid_layout(cfg)
    dt = _dtype(cfg)
    st = M2.mamba2_init_state(None, batch, cfg.d_model, cfg.ssm, dt)
    kd = cfg.resolved_head_dim
    return {
        "mamba": jax.tree.map(lambda a: jnp.broadcast_to(a, (G, E) + a.shape), st),
        "k": jnp.zeros((G, batch, max_len, cfg.num_kv_heads, kd), dt),
        "v": jnp.zeros((G, batch, max_len, cfg.num_kv_heads, kd), dt),
    }


def hybrid_prefill(params, cfg: ModelCfg, tokens, max_len: int):
    B, S = tokens.shape
    logits, aux = hybrid_forward(params, cfg, tokens, collect_cache=True)
    m_states, (k, v) = aux
    pad = max_len - S
    return logits[:, -1], {
        "mamba": m_states,
        "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
    }


def hybrid_decode_step(params, cfg: ModelCfg, token, cache, pos):
    x = params["embed"][token][:, None, :]
    sh = params["shared_attn"]

    def group_body(x, g):
        pm, lns, mst, k_g, v_g = g

        def m_body(x, xs):
            pl, ln, st = xs
            out, st_n = M2.mamba2_decode(pl, L.rmsnorm(ln, x), st, cfg.ssm)
            return x + out, st_n

        x, mst_n = jax.lax.scan(m_body, x, (pm, lns, mst))
        h = L.rmsnorm(sh["ln1"], x)
        attn_out, k_n, v_n = A.self_attn_decode(
            sh["attn"], h, k_g, v_g, pos, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            theta=cfg.rope_theta)
        x = x + attn_out
        x = x + L.mlp_apply(sh["mlp"], L.rmsnorm(sh["ln2"], x))
        return x, (mst_n, k_n, v_n)

    x, (mst, k, v) = jax.lax.scan(
        group_body, x,
        (params["mamba"], params["mln"], cache["mamba"], cache["k"], cache["v"]))
    logits = _head(params, cfg, x)
    return logits[:, 0], {"mamba": mst, "k": k, "v": v}
