"""Logical-axis sharding annotations for model code.

Model code annotates activations with *logical* axes ("batch", "seq", "model",
"ff", ...). The launcher installs a logical->mesh mapping (e.g. batch ->
("pod", "data")); outside any mapping the annotations are no-ops so unit tests
and CPU smoke tests never touch device state.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[str, Sequence[str], None]

_state = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_axis_rules(rules: dict[str, Axis]):
    """Install logical->mesh axis mapping, e.g. {"batch": ("pod", "data"),
    "model": "model"}. Unknown logical names map to None (replicated)."""
    prev = _rules()
    _state.rules = dict(rules)
    try:
        yield
    finally:
        _state.rules = prev


def resolve(*logical: Optional[str]) -> P:
    rules = _rules() or {}
    return P(*[rules.get(a) if a is not None else None for a in logical])


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the installed rules; no-op otherwise."""
    if _rules() is None:
        return x
    return jax.lax.with_sharding_constraint(x, resolve(*logical))
