"""xLSTM blocks (mLSTM + sLSTM), TPU-adapted.

mLSTM (matrix memory, exponential gating) admits a parallel quadratic form
structurally identical to attention with a data-dependent decay matrix
``D[t,s] = exp(cumf_t - cumf_s + i_s)``. We implement it blockwise with the
same online-max rescaling trick as flash attention (fori over KV blocks, scan
over query blocks), so 32k prefill never materializes S x S. Decode is the
O(P^2) recurrence on the (P x P) matrix state.

sLSTM is *intrinsically serial* (hidden-state -> gate recurrence, per-head
block-diagonal R). There is no parallel form — this is the architecture's own
property, not a porting artifact — so training runs a lax.scan over time. The
1.3b config uses mLSTM:sLSTM = 7:1, so the serial fraction is small.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -1e30


# =============================== mLSTM =====================================

class MLSTMState(NamedTuple):
    C: jnp.ndarray   # (B, H, P, P) matrix memory
    n: jnp.ndarray   # (B, H, P) normalizer
    m: jnp.ndarray   # (B, H) stabilizer


def mlstm_init(key, d_model: int, num_heads: int, dtype, pf: float = 2.0):
    d_inner = int(pf * d_model)
    ks = jax.random.split(key, 8)
    return {
        "w_up": L.dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "w_q": L.dense_init(ks[1], d_inner, d_inner, dtype),
        "w_k": L.dense_init(ks[2], d_inner, d_inner, dtype),
        "w_v": L.dense_init(ks[3], d_inner, d_inner, dtype),
        "w_i": L.dense_init(ks[4], d_inner, num_heads, jnp.float32),
        "w_f": L.dense_init(ks[5], d_inner, num_heads, jnp.float32),
        "b_i": jnp.zeros((num_heads,), jnp.float32),
        "b_f": jnp.full((num_heads,), 3.0, jnp.float32),   # open forget gates
        "w_down": L.dense_init(ks[6], d_inner, d_model, dtype),
        "norm": L.rmsnorm_init(d_inner),
    }


def _mlstm_qkvif(params, x, num_heads):
    B, S, _ = x.shape
    up = x @ params["w_up"]
    xi, z = jnp.split(up, 2, axis=-1)                    # inner stream + gate
    d_inner = xi.shape[-1]
    P = d_inner // num_heads
    q = (xi @ params["w_q"]).reshape(B, S, num_heads, P)
    k = (xi @ params["w_k"]).reshape(B, S, num_heads, P) / math.sqrt(P)
    v = (xi @ params["w_v"]).reshape(B, S, num_heads, P)
    it = xi.astype(jnp.float32) @ params["w_i"] + params["b_i"]   # (B,S,H)
    ft = xi.astype(jnp.float32) @ params["w_f"] + params["b_f"]
    return q, k, v, it, ft, z, d_inner, P


def _mlstm_parallel(q, k, v, it, ft, *, block_q: int = 256, block_kv: int = 512):
    """Blockwise stabilized quadratic mLSTM. q,k,v: (B,S,H,P); it,ft: (B,S,H)."""
    B, S, H, P = q.shape
    logf = jax.nn.log_sigmoid(ft)                        # (B,S,H)
    cum = jnp.cumsum(logf, axis=1)                       # inclusive cumsum
    # weight for pair (t, s): exp(cum_t - cum_s + i_s), s <= t
    bq = min(block_q, S)
    bkv = min(block_kv, S)
    pq, pkv = (-S) % bq, (-S) % bkv
    qf = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, pq), (0, 0), (0, 0)))
    cumq = jnp.pad(cum, ((0, 0), (0, pq), (0, 0)))
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, pkv), (0, 0), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, pkv), (0, 0), (0, 0)))
    cumk = jnp.pad(cum, ((0, 0), (0, pkv), (0, 0)))
    itp = jnp.pad(it, ((0, 0), (0, pkv), (0, 0)), constant_values=NEG_INF)
    nq, nkv = (S + pq) // bq, (S + pkv) // bkv

    qb = qf.reshape(B, nq, bq, H, P).transpose(1, 0, 2, 3, 4)
    cumqb = cumq.reshape(B, nq, bq, H).transpose(1, 0, 2, 3)

    def q_block(carry, inp):
        qblk, cq, qi = inp                               # (B,bq,H,P), (B,bq,H)
        q_start = qi * bq

        def body(t, st):
            m, num, den = st
            kblk = jax.lax.dynamic_slice_in_dim(kf, t * bkv, bkv, 1)
            vblk = jax.lax.dynamic_slice_in_dim(vf, t * bkv, bkv, 1)
            ck = jax.lax.dynamic_slice_in_dim(cumk, t * bkv, bkv, 1)
            ik = jax.lax.dynamic_slice_in_dim(itp, t * bkv, bkv, 1)
            k_pos = t * bkv + jnp.arange(bkv)
            q_pos = q_start + jnp.arange(bq)
            causal = q_pos[:, None] >= k_pos[None, :]    # (bq,bkv)
            # logD: (B,bq,bkv,H)
            logD = cq[:, :, None, :] - ck[:, None, :, :] + ik[:, None, :, :]
            logD = jnp.where(causal[None, :, :, None], logD, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logD, axis=2))          # (B,bq,H)
            # explicit mask: fully-masked blocks (m_new == NEG_INF) must add 0
            w = jnp.exp(logD - m_new[:, :, None, :]) \
                * causal[None, :, :, None].astype(jnp.float32)
            corr = jnp.exp(m - m_new)
            qk = jnp.einsum("bqhp,bjhp->bqjh", qblk, kblk)         # (B,bq,bkv,H)
            wqk = w * qk
            num_new = num * corr[..., None] + jnp.einsum(
                "bqjh,bjhp->bqhp", wqk, vblk)
            den_new = den * corr + jnp.sum(wqk, axis=2)
            return m_new, num_new, den_new

        m0 = jnp.full((B, bq, H), NEG_INF, jnp.float32)
        n0 = jnp.zeros((B, bq, H, P), jnp.float32)
        d0 = jnp.zeros((B, bq, H), jnp.float32)
        # full-range masked scan: reverse-mode differentiable and visible to
        # the HLO loop-cost accounting (static trip count)
        (m, num, den), _ = jax.lax.scan(
            lambda st, t: (body(t, st), 0), (m0, n0, d0), jnp.arange(nkv))
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        return carry, y

    _, ys = jax.lax.scan(q_block, 0, (qb, cumqb, jnp.arange(nq)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nq * bq, H, P)[:, :S]
    return y


def mlstm_apply(params, x, num_heads: int, return_state: bool = False):
    B, S, d_model = x.shape
    q, k, v, it, ft, z, d_inner, P = _mlstm_qkvif(params, x, num_heads)
    y = _mlstm_parallel(q, k, v, it, ft)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y) * jax.nn.silu(z)
    out = y @ params["w_down"]
    if not return_state:
        return out
    # closed-form final state: C_S = sum_s exp(cum_S - cum_s + i_s - m) v_s k_s^T
    logf = jax.nn.log_sigmoid(ft)
    cum = jnp.cumsum(logf, axis=1)                        # (B,S,H)
    logw = cum[:, -1:, :] - cum + it                      # (B,S,H)
    m_fin = jnp.max(logw, axis=1)                         # (B,H)
    w = jnp.exp(logw - m_fin[:, None, :])                 # (B,S,H)
    C = jnp.einsum("bsh,bshp,bshq->bhpq", w, v.astype(jnp.float32),
                   k.astype(jnp.float32))
    n = jnp.einsum("bsh,bshq->bhq", w, k.astype(jnp.float32))
    return out, MLSTMState(C=C, n=n, m=m_fin)


def mlstm_init_state(batch, d_model, num_heads, pf: float = 2.0):
    d_inner = int(pf * d_model)
    P = d_inner // num_heads
    return MLSTMState(
        C=jnp.zeros((batch, num_heads, P, P), jnp.float32),
        n=jnp.zeros((batch, num_heads, P), jnp.float32),
        m=jnp.full((batch, num_heads), NEG_INF, jnp.float32),
    )


def mlstm_decode(params, x, state: MLSTMState, num_heads: int
                 ) -> Tuple[jnp.ndarray, MLSTMState]:
    """x: (B, 1, d)."""
    B, _, d_model = x.shape
    q, k, v, it, ft, z, d_inner, P = _mlstm_qkvif(params, x, num_heads)
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]               # (B,H,P)
    i1, f1 = it[:, 0], ft[:, 0]                          # (B,H)
    logf = jax.nn.log_sigmoid(f1)
    m_new = jnp.maximum(state.m + logf, i1)
    a = jnp.exp(state.m + logf - m_new)                  # decay of old state
    b = jnp.exp(i1 - m_new)                              # write strength
    C = a[..., None, None] * state.C + b[..., None, None] * jnp.einsum(
        "bhp,bhq->bhpq", v1.astype(jnp.float32), k1.astype(jnp.float32))
    n = a[..., None] * state.n + b[..., None] * k1.astype(jnp.float32)
    num = jnp.einsum("bhpq,bhq->bhp", C, q1.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhq,bhq->bh", n, q1.astype(jnp.float32))),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, d_inner).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y) * jax.nn.silu(z)
    return y @ params["w_down"], MLSTMState(C=C, n=n, m=m_new)


# =============================== sLSTM =====================================

class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, d_inner)
    n: jnp.ndarray   # (B, d_inner)
    h: jnp.ndarray   # (B, d_inner)
    m: jnp.ndarray   # (B, d_inner)


def slstm_init(key, d_model: int, num_heads: int, dtype, pf: float = 4.0 / 3.0):
    d_inner = (int(pf * d_model) // num_heads) * num_heads
    P = d_inner // num_heads
    ks = jax.random.split(key, 4)
    return {
        "w_in": L.dense_init(ks[0], d_model, 4 * d_inner, dtype),
        # block-diagonal recurrent weights per head: h (P) -> gates (4P)
        "R": (jax.random.normal(ks[1], (num_heads, P, 4 * P))
              / math.sqrt(P)).astype(jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d_inner,), jnp.float32),
                              jnp.full((d_inner,), 3.0, jnp.float32),
                              jnp.zeros((d_inner,), jnp.float32)]),
        "w_down": L.dense_init(ks[2], d_inner, d_model, dtype),
        "norm": L.rmsnorm_init(d_inner),
    }


def _slstm_cell(gates, st: SLSTMState, d_inner: int) -> SLSTMState:
    zt, it, ft, ot = jnp.split(gates, 4, axis=-1)        # each (B, d_inner)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + st.m, it)
    i = jnp.exp(it - m_new)
    f = jnp.exp(logf + st.m - m_new)
    c = f * st.c + i * jnp.tanh(zt)
    n = jnp.maximum(f * st.n + i, 1.0)
    h = jax.nn.sigmoid(ot) * c / n
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def _slstm_gates(params, xt, h_prev, num_heads, d_inner):
    """xt: (B, 4*d_inner) pre-proj input; h_prev: (B, d_inner)."""
    B = h_prev.shape[0]
    P = d_inner // num_heads
    hh = h_prev.reshape(B, num_heads, P)
    rec = jnp.einsum("bhp,hpg->bhg", hh, params["R"]).reshape(B, num_heads, 4, P)
    rec = rec.transpose(0, 2, 1, 3).reshape(B, 4 * d_inner)
    return xt.astype(jnp.float32) + rec + params["b"]


def slstm_apply(params, x, num_heads: int):
    """Serial scan over time (no parallel form exists)."""
    B, S, d_model = x.shape
    d_inner4 = params["w_in"].shape[1]
    d_inner = d_inner4 // 4
    xin = (x @ params["w_in"]).astype(jnp.float32)        # (B,S,4*di)

    def step(st, xt):
        gates = _slstm_gates(params, xt, st.h, num_heads, d_inner)
        st = _slstm_cell(gates, st, d_inner)
        return st, st.h

    st0 = SLSTMState(*[jnp.zeros((B, d_inner), jnp.float32) for _ in range(3)],
                     m=jnp.full((B, d_inner), NEG_INF, jnp.float32))
    _, hs = jax.lax.scan(step, st0, xin.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)             # (B,S,d_inner)
    y = L.rmsnorm(params["norm"], y)
    return y @ params["w_down"]


def slstm_init_state(batch, d_model, num_heads, pf: float = 4.0 / 3.0):
    d_inner = (int(pf * d_model) // num_heads) * num_heads
    z = jnp.zeros((batch, d_inner), jnp.float32)
    return SLSTMState(c=z, n=z, h=z,
                      m=jnp.full((batch, d_inner), NEG_INF, jnp.float32))


def slstm_decode(params, x, state: SLSTMState, num_heads: int):
    B, _, d_model = x.shape
    d_inner = params["w_in"].shape[1] // 4
    xt = (x[:, 0] @ params["w_in"]).astype(jnp.float32)
    gates = _slstm_gates(params, xt, state.h, num_heads, d_inner)
    st = _slstm_cell(gates, state, d_inner)
    y = L.rmsnorm(params["norm"], st.h[:, None, :].astype(x.dtype))
    return y @ params["w_down"], st
