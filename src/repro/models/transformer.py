"""Decoder-only transformer assembly (dense / MoE / MLA / VLM families).

Layers are *scanned*: parameters for homogeneous layer stacks are stored with a
leading layer axis and the forward pass is a lax.scan over that axis, so HLO
size (and compile time) is independent of depth — essential for 62-layer
configs compiled for 512 devices on one CPU. Per-layer heterogeneity
(gemma3's 5:1 local:global window pattern, per-layer rope theta) rides along
as scanned *data* (arrays of windows/thetas), not as structure.

VLM (llama-3.2-vision style): layers are grouped; each group is
(cross_attn_every - 1) self-attn layers + 1 cross-attn layer, scanned over
groups with an inner scan over the self layers.

Caches: pytrees with a leading layer axis; decode scans over layers carrying
the token activation and threading per-layer cache slices as scan xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models.sharding import constrain


def _dtype(cfg: ModelCfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------------------------------------------------ init ---

def _layer_init(key, cfg: ModelCfg):
    """One decoder layer's params."""
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": L.rmsnorm_init(cfg.d_model),
                         "ln2": L.rmsnorm_init(cfg.d_model)}
    if cfg.mla is not None:
        p["attn"] = MLA.mla_init(ks[0], cfg.d_model, cfg.num_heads, cfg.mla, dt)
    else:
        p["attn"] = A.attn_init(ks[0], cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, cfg.resolved_head_dim, dt,
                                qkv_bias=cfg.qkv_bias)
    if cfg.moe is not None:
        p["ffn"] = MOE.moe_init(ks[1], cfg.d_model, cfg.moe, cfg.d_ff, dt)
    else:
        p["ffn"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt,
                              gated=cfg.gated_mlp)
    return p


def _cross_layer_init(key, cfg: ModelCfg):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "attn": A.attn_init(ks[0], cfg.d_model, cfg.num_heads,
                            cfg.num_kv_heads, cfg.resolved_head_dim, dt),
        "ffn": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, gated=cfg.gated_mlp),
        "gate": jnp.zeros((), jnp.float32),   # zero-init cross-attn gate
    }


def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def transformer_init(key, cfg: ModelCfg):
    dt = _dtype(cfg)
    k_embed, k_layers, k_cross, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": L.embed_init(k_embed, cfg.vocab_size, cfg.d_model, dt),
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }
    if cfg.cross_attn_every:
        per = cfg.cross_attn_every
        groups = cfg.num_layers // per
        params["groups"] = {
            "self": _stacked(
                lambda k: _stacked(lambda kk: _layer_init(kk, cfg), k, per - 1),
                k_layers, groups),
            "cross": _stacked(lambda k: _cross_layer_init(k, cfg),
                              k_cross, groups),
        }
        params["img_proj"] = L.dense_init(k_head, cfg.d_model, cfg.d_model, dt)
    else:
        params["layers"] = _stacked(lambda k: _layer_init(k, cfg),
                                    k_layers, cfg.num_layers)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_size, dt)
    return params


# --------------------------------------------------------------- forward ---

def _ffn_apply(p_ffn, cfg: ModelCfg, h):
    if cfg.moe is not None:
        y, aux = MOE.moe_apply(p_ffn, h, cfg.moe)
        return y, aux
    return L.mlp_apply(p_ffn, h, act=cfg.act, gated=cfg.gated_mlp), 0.0


def _self_layer(p, cfg: ModelCfg, x, window, theta, q_offset: int = 0,
                differentiable: bool = False):
    """Returns (x_out, aux, kv) — kv is the prefill cache contribution."""
    h = L.rmsnorm(p["ln1"], x)
    if cfg.mla is not None:
        attn_out, kv = MLA.mla_prefill(p["attn"], h, num_heads=cfg.num_heads,
                                       cfg=cfg.mla, theta=theta,
                                       q_offset=q_offset,
                                       differentiable=differentiable)
    else:
        attn_out, kv = A.self_attn_apply(
            p["attn"], h, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            theta=theta, window=window, q_offset=q_offset,
            differentiable=differentiable)
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x)
    ffn_out, aux = _ffn_apply(p["ffn"], cfg, h)
    x = constrain(x + ffn_out, "batch", "seq", None)
    return x, aux, kv


def _cross_layer(p, cfg: ModelCfg, x, kv_k, kv_v, differentiable: bool = False):
    h = L.rmsnorm(p["ln1"], x)
    attn_out = A.cross_attn_apply(p["attn"], h, kv_k, kv_v,
                                  num_heads=cfg.num_heads,
                                  num_kv_heads=cfg.num_kv_heads,
                                  head_dim=cfg.resolved_head_dim,
                                  differentiable=differentiable)
    x = x + (jnp.tanh(p["gate"]).astype(attn_out.dtype) * attn_out)
    h = L.rmsnorm(p["ln2"], x)
    ffn_out, _ = _ffn_apply(p["ffn"], cfg, h)
    return x + ffn_out


def transformer_forward(params, cfg: ModelCfg, tokens: jnp.ndarray,
                        image_embed: Optional[jnp.ndarray] = None,
                        remat: bool = False,
                        collect_cache: bool = False,
                        return_hidden: bool = False):
    """tokens: (B, S) -> (logits (B,S,V) f32, aux, cache|None).
    ``return_hidden``: skip the unembedding and return the final normed
    hidden states instead (the fused-CE loss path computes logits in chunks
    so the full (B,S,V) tensor is never materialized)."""
    x = params["embed"][tokens]
    x = constrain(x, "batch", "seq", None)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    thetas = jnp.asarray(cfg.layer_thetas(), jnp.float32)
    diff = not collect_cache   # training path must be reverse-differentiable

    if cfg.cross_attn_every:
        img = image_embed @ params["img_proj"]

        def group_body(x, g):
            p_self, p_cross = g

            def self_body(x, pl):
                y, aux, kv = _self_layer(pl, cfg, x, 0, cfg.rope_theta,
                                         differentiable=diff)
                return y, (aux, kv)
            body = jax.checkpoint(self_body) if remat else self_body
            x, (auxs, kvs) = jax.lax.scan(body, x, p_self)
            kk, vv = A.cross_kv(p_cross["attn"], img,
                                num_kv_heads=cfg.num_kv_heads,
                                head_dim=cfg.resolved_head_dim)
            x = _cross_layer(p_cross, cfg, x, kk, vv, differentiable=diff)
            return x, (jnp.sum(auxs), kvs, (kk, vv))

        gbody = jax.checkpoint(group_body) if remat else group_body
        x, (auxs, kvs, xkvs) = jax.lax.scan(
            gbody, x, (params["groups"]["self"], params["groups"]["cross"]))
        aux = jnp.sum(auxs)
        cache = (kvs, xkvs) if collect_cache else None
    else:
        def body(x, xs):
            pl, w, th = xs
            y, aux, kv = _self_layer(pl, cfg, x, w, th, differentiable=diff)
            return y, (aux, kv)

        lbody = jax.checkpoint(body) if remat else body
        x, (auxs, kvs) = jax.lax.scan(lbody, x, (params["layers"], windows, thetas))
        aux = jnp.sum(auxs)
        cache = kvs if collect_cache else None

    x = L.rmsnorm(params["ln_f"], x)
    if return_hidden:
        return x, aux, cache
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = (x @ params["lm_head"]).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "vocab")
    return logits, aux, cache


def head_matrix(params, cfg: ModelCfg):
    """(V, d) unembedding matrix (tied or separate) for the fused CE."""
    if cfg.tie_embeddings:
        return params["embed"]
    return params["lm_head"].T


# ----------------------------------------------------------------- cache ---

def init_kv_cache(cfg: ModelCfg, batch: int, max_len: int):
    dt = _dtype(cfg)
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((cfg.num_layers, batch, max_len, m.kv_lora_rank), dt),
            "krope": jnp.zeros((cfg.num_layers, batch, max_len, m.rope_head_dim), dt),
        }
    kd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, kd)
    cache = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if cfg.cross_attn_every:
        per = cfg.cross_attn_every
        groups = cfg.num_layers // per
        cache = {
            "k": jnp.zeros((groups, per - 1, batch, max_len, cfg.num_kv_heads, kd), dt),
            "v": jnp.zeros((groups, per - 1, batch, max_len, cfg.num_kv_heads, kd), dt),
            "xk": jnp.zeros((groups, batch, cfg.num_image_tokens,
                             cfg.num_kv_heads, kd), dt),
            "xv": jnp.zeros((groups, batch, cfg.num_image_tokens,
                             cfg.num_kv_heads, kd), dt),
        }
    return cache


def transformer_prefill(params, cfg: ModelCfg, tokens: jnp.ndarray,
                        max_len: int,
                        image_embed: Optional[jnp.ndarray] = None):
    """Run the full prompt, return (last-position logits, cache at max_len).
    Only the last position is unembedded (V x d matmul on (B, 1) instead of
    (B, S) — a 32768x flop/memory saving on the 32k prefill cells)."""
    B, S = tokens.shape
    x, _, kvs = transformer_forward(params, cfg, tokens,
                                    image_embed=image_embed,
                                    collect_cache=True, return_hidden=True)
    x_last = x[:, -1:]
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x_last)
    else:
        logits = (x_last @ params["lm_head"]).astype(jnp.float32)
    pad = max_len - S
    if cfg.cross_attn_every:
        (k, v), (xk, xv) = kvs
        # k/v: (groups, per-1, B, S, KV, Dh) stacked by the nested scans
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "xk": xk, "xv": xv,
        }
        return logits[:, 0], cache
    if cfg.mla is not None:
        ckv, krope = kvs
        cache = {
            "ckv": jnp.pad(ckv, ((0, 0), (0, 0), (0, pad), (0, 0))),
            "krope": jnp.pad(krope, ((0, 0), (0, 0), (0, pad), (0, 0))),
        }
    else:
        k, v = kvs
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        }
    return logits[:, 0], cache


def transformer_decode_step(params, cfg: ModelCfg, token: jnp.ndarray,
                            cache, pos,
                            image_embed: Optional[jnp.ndarray] = None):
    """token: (B,) int32; pos: scalar int32 position to write. Returns
    (logits (B, V) f32, new cache)."""
    x = params["embed"][token][:, None, :]               # (B, 1, d)
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)
    thetas = jnp.asarray(cfg.layer_thetas(), jnp.float32)

    if cfg.cross_attn_every:
        def group_body(x, g):
            (p_self, p_cross, ck, cv, xk, xv) = g

            def self_body(x, xs):
                pl, k_l, v_l = xs
                h = L.rmsnorm(pl["ln1"], x)
                attn_out, k_n, v_n = A.self_attn_decode(
                    pl["attn"], h, k_l, v_l, pos, num_heads=cfg.num_heads,
                    num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.resolved_head_dim, theta=cfg.rope_theta)
                x = x + attn_out
                h = L.rmsnorm(pl["ln2"], x)
                ffn_out, _ = _ffn_apply(pl["ffn"], cfg, h)
                return x + ffn_out, (k_n, v_n)

            x, (k_new, v_new) = jax.lax.scan(self_body, x, (p_self, ck, cv))
            x = _cross_layer(p_cross, cfg, x, xk, xv)   # cached cross K/V
            return x, (k_new, v_new)

        x, (k_new, v_new) = jax.lax.scan(
            group_body, x,
            (params["groups"]["self"], params["groups"]["cross"],
             cache["k"], cache["v"], cache["xk"], cache["xv"]))
        cache = dict(cache, k=k_new, v=v_new)
    elif cfg.mla is not None:
        def body(x, xs):
            pl, ckv_l, krope_l = xs
            h = L.rmsnorm(pl["ln1"], x)
            attn_out, ckv_n, krope_n = MLA.mla_decode(
                pl["attn"], h, ckv_l, krope_l, pos,
                num_heads=cfg.num_heads, cfg=cfg.mla, theta=cfg.rope_theta)
            x = x + attn_out
            h = L.rmsnorm(pl["ln2"], x)
            ffn_out, _ = _ffn_apply(pl["ffn"], cfg, h)
            return x + ffn_out, (ckv_n, krope_n)

        x, (ckv, krope) = jax.lax.scan(
            body, x, (params["layers"], cache["ckv"], cache["krope"]))
        cache = {"ckv": ckv, "krope": krope}
    else:
        def body(x, xs):
            pl, w, th, k_l, v_l = xs
            h = L.rmsnorm(pl["ln1"], x)
            attn_out, k_n, v_n = A.self_attn_decode(
                pl["attn"], h, k_l, v_l, pos, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                theta=th, window=w)
            x = x + attn_out
            h = L.rmsnorm(pl["ln2"], x)
            ffn_out, _ = _ffn_apply(pl["ffn"], cfg, h)
            return x + ffn_out, (k_n, v_n)

        x, (k, v) = jax.lax.scan(
            body, x, (params["layers"], windows, thetas, cache["k"], cache["v"]))
        cache = {"k": k, "v": v}

    x = L.rmsnorm(params["ln_f"], x)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = (x @ params["lm_head"]).astype(jnp.float32)
    return logits[:, 0], cache
