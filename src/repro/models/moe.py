"""Top-k routed Mixture-of-Experts with capacity-bounded einsum dispatch.

Dispatch is the Mesh-TF/Switch pattern (one-hot dispatch/combine tensors) so it
shards cleanly: the expert axis is a *logical* axis ("expert") that the
launcher maps to the mesh's model axis when num_experts divides it (EP), or
leaves replicated with the expert FFN hidden dim tensor-parallel instead (TP).

To bound the (tokens, E, C) dispatch tensor, tokens are processed in groups of
``group`` with a lax.scan — capacity is per-group, which also matches how
production routers bound hot-expert skew. An auxiliary load-balancing loss
(Switch style) is returned alongside.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoECfg
from repro.models import layers as L
from repro.models.sharding import constrain


def moe_init(key, d_model: int, cfg: MoECfg, d_ff_dense: int, dtype):
    d_e = cfg.d_expert or d_ff_dense
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], d_model, cfg.num_experts, jnp.float32),
        "w_gate": _stack_init(ks[1], cfg.num_experts, d_model, d_e, dtype),
        "w_up": _stack_init(ks[2], cfg.num_experts, d_model, d_e, dtype),
        "w_down": _stack_init(ks[3], cfg.num_experts, d_e, d_model, dtype),
    }
    if cfg.num_shared:
        p["shared"] = L.mlp_init(ks[4], d_model, d_e * cfg.num_shared, dtype)
    return p


def _stack_init(key, e, d_in, d_out, dtype):
    std = 1.0 / (d_in ** 0.5)
    return (jax.random.normal(key, (e, d_in, d_out)) * std).astype(dtype)


def moe_apply(params, x: jnp.ndarray, cfg: MoECfg, *,
              group: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    g = min(group, S)
    pad = (-S) % g
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    ng = xp.shape[1] // g
    cap = max(1, int(cfg.capacity_factor * g * K / E))

    xg = xp.reshape(B, ng, g, d).transpose(1, 0, 2, 3)      # (ng, B, g, d)

    def one_group(carry, xt):                                # xt: (B, g, d)
        logits = (xt.astype(jnp.float32) @ params["router"])  # (B, g, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, K)         # (B, g, K)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B, g, K, E)
        # position of each (token, k) slot in its expert queue (k-major order)
        flat = onehot.reshape(B, g * K, E)
        pos = jnp.cumsum(flat, axis=1) - flat                 # (B, g*K, E)
        pos = pos.reshape(B, g, K, E)
        keep = (pos < cap) * onehot
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        # dispatch / combine: (B, g, E, C)
        dispatch = jnp.einsum("bgke,bgkec->bgec", keep, pos_oh * onehot[..., None])
        combine = jnp.einsum("bgke,bgkec->bgec",
                             keep * gate_vals[..., None], pos_oh * onehot[..., None])

        ein = jnp.einsum("bgec,bgd->becd", dispatch, xt.astype(jnp.float32))
        ein = constrain(ein.astype(xt.dtype), "batch", "expert", None, None)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", ein, params["w_gate"])) \
            * jnp.einsum("becd,edf->becf", ein, params["w_up"])
        out_e = jnp.einsum("becf,efd->becd", h, params["w_down"])
        out_e = constrain(out_e, "batch", "expert", None, None)
        y = jnp.einsum("bgec,becd->bgd", combine, out_e.astype(jnp.float32))

        # Switch aux loss: fraction routed * mean router prob, per expert
        frac = jnp.mean(onehot[..., 0:K, :].sum(2), axis=1)   # (B, E)
        imp = jnp.mean(probs, axis=1)                         # (B, E)
        aux = E * jnp.mean(jnp.sum(frac * imp, axis=-1))
        return carry + aux, y.astype(xt.dtype)

    aux, yg = jax.lax.scan(one_group, jnp.zeros((), jnp.float32), xg)
    y = yg.transpose(1, 0, 2, 3).reshape(B, ng * g, d)[:, :S]
    if "shared" in params:
        y = y + L.mlp_apply(params["shared"], x)
    return y, aux / ng
