"""Whisper-style encoder-decoder backbone.

Per the assignment spec the conv audio frontend is a STUB: ``input_specs``
feeds precomputed frame embeddings (B, S_enc, d_model). The backbone is
faithful Whisper: pre-LN transformer, non-causal encoder self-attention,
decoder with causal self-attention + cross-attention, GELU MLPs (non-gated),
sinusoidal encoder positions / learned decoder positions.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.models import attention as A
from repro.models import layers as L
from repro.models.sharding import constrain


def _dtype(cfg: ModelCfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _sinusoid(length: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(key, cfg: ModelCfg, dt):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.layernorm_init(cfg.d_model),
        "attn": A.attn_init(ks[0], cfg.d_model, cfg.num_heads,
                            cfg.num_kv_heads, cfg.resolved_head_dim, dt,
                            qkv_bias=True),
        "ln2": L.layernorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, gated=False),
    }


def _dec_layer_init(key, cfg: ModelCfg, dt):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.layernorm_init(cfg.d_model),
        "self": A.attn_init(ks[0], cfg.d_model, cfg.num_heads,
                            cfg.num_kv_heads, cfg.resolved_head_dim, dt,
                            qkv_bias=True),
        "ln_x": L.layernorm_init(cfg.d_model),
        "cross": A.attn_init(ks[1], cfg.d_model, cfg.num_heads,
                             cfg.num_kv_heads, cfg.resolved_head_dim, dt,
                             qkv_bias=True),
        "ln2": L.layernorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dt, gated=False),
    }


def encdec_init(key, cfg: ModelCfg):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 5)
    enc_l = cfg.encoder_layers or cfg.num_layers
    return {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "pos_dec": L.embed_init(ks[1], 8192, cfg.d_model, dt),
        "enc": jax.vmap(lambda k: _enc_layer_init(k, cfg, dt))(
            jax.random.split(ks[2], enc_l)),
        "dec": jax.vmap(lambda k: _dec_layer_init(k, cfg, dt))(
            jax.random.split(ks[3], cfg.num_layers)),
        "ln_enc": L.layernorm_init(cfg.d_model),
        "ln_f": L.layernorm_init(cfg.d_model),
    }


def encode(params, cfg: ModelCfg, frames: jnp.ndarray,
           differentiable: bool = False) -> jnp.ndarray:
    """frames: (B, S_enc, d_model) precomputed embeddings (conv stub)."""
    B, S, d = frames.shape
    x = frames + _sinusoid(S, d).astype(frames.dtype)[None]
    x = constrain(x, "batch", None, None)

    def body(x, pl):
        h = L.layernorm(pl["ln1"], x)
        q, k, v = A._project_qkv(pl["attn"], h, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim)
        attn = A.flash_attention(q, k, v, causal=False, window=0,
                                 differentiable=differentiable)
        attn = attn.reshape(B, S, -1) @ pl["attn"]["wo"]
        x = x + attn
        x = x + L.mlp_apply(pl["mlp"], L.layernorm(pl["ln2"], x),
                            act="gelu", gated=False)
        return x, 0.0

    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.layernorm(params["ln_enc"], x)


def decode_train(params, cfg: ModelCfg, tokens: jnp.ndarray,
                 enc_out: jnp.ndarray, remat: bool = False,
                 collect_cache: bool = False, return_hidden: bool = False):
    """Teacher-forced decoder pass -> (logits, cache)."""
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos_dec"][jnp.arange(S)][None]

    def body(x, pl):
        h = L.layernorm(pl["ln1"], x)
        q, k, v = A._project_qkv(pl["self"], h, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.resolved_head_dim)
        attn = A.flash_attention(q, k, v, causal=True, window=0,
                                 differentiable=not collect_cache)
        x = x + attn.reshape(B, S, -1) @ pl["self"]["wo"]
        h = L.layernorm(pl["ln_x"], x)
        kk, vv = A.cross_kv(pl["cross"], enc_out,
                            num_kv_heads=cfg.num_kv_heads,
                            head_dim=cfg.resolved_head_dim)
        x = x + A.cross_attn_apply(pl["cross"], h, kk, vv,
                                   num_heads=cfg.num_heads,
                                   num_kv_heads=cfg.num_kv_heads,
                                   head_dim=cfg.resolved_head_dim,
                                   differentiable=not collect_cache)
        x = x + L.mlp_apply(pl["mlp"], L.layernorm(pl["ln2"], x),
                            act="gelu", gated=False)
        return x, ((k, v), (kk, vv)) if collect_cache else (x, 0.0)[1]

    lbody = jax.checkpoint(body) if remat else body
    x, caches = jax.lax.scan(lbody, x, params["dec"])
    x = L.layernorm(params["ln_f"], x)
    if return_hidden:
        return x, caches if collect_cache else None
    logits = constrain(L.unembed(params["embed"], x), "batch", None, "vocab")
    return logits, caches if collect_cache else None


def encdec_init_cache(cfg: ModelCfg, batch: int, max_len: int):
    dt = _dtype(cfg)
    kd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, kd), dt),
        "v": jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, kd), dt),
        "xk": jnp.zeros((cfg.num_layers, batch, cfg.num_audio_frames,
                         cfg.num_kv_heads, kd), dt),
        "xv": jnp.zeros((cfg.num_layers, batch, cfg.num_audio_frames,
                         cfg.num_kv_heads, kd), dt),
    }


def encdec_prefill(params, cfg: ModelCfg, tokens, frames, max_len: int):
    B, S = tokens.shape
    enc_out = encode(params, cfg, frames)
    logits, caches = decode_train(params, cfg, tokens, enc_out,
                                  collect_cache=True)
    (k, v), (xk, xv) = caches
    pad = max_len - S
    cache = {
        "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "xk": xk, "xv": xv,
    }
    return logits[:, -1], cache


def encdec_decode_step(params, cfg: ModelCfg, token, cache, pos):
    B = token.shape[0]
    posv = jnp.asarray(pos, jnp.int32)
    x = params["embed"][token][:, None, :] + params["pos_dec"][posv][None, None]

    def body(x, xs):
        pl, k_l, v_l, xk_l, xv_l = xs
        h = L.layernorm(pl["ln1"], x)
        q = (h @ pl["self"]["wq"] + pl["self"]["bq"]).reshape(
            B, 1, cfg.num_heads, cfg.resolved_head_dim)
        k = (h @ pl["self"]["wk"] + pl["self"]["bk"]).reshape(
            B, 1, cfg.num_kv_heads, cfg.resolved_head_dim)
        v = (h @ pl["self"]["wv"] + pl["self"]["bv"]).reshape(
            B, 1, cfg.num_kv_heads, cfg.resolved_head_dim)
        k_l = jax.lax.dynamic_update_slice_in_dim(k_l, k.astype(k_l.dtype),
                                                  pos, axis=1)
        v_l = jax.lax.dynamic_update_slice_in_dim(v_l, v.astype(v_l.dtype),
                                                  pos, axis=1)
        attn = A.decode_attention(q[:, 0], k_l, v_l, pos)
        x = x + attn.reshape(B, 1, -1) @ pl["self"]["wo"]
        h = L.layernorm(pl["ln_x"], x)
        x = x + A.cross_attn_apply(pl["cross"], h, xk_l, xv_l,
                                   num_heads=cfg.num_heads,
                                   num_kv_heads=cfg.num_kv_heads,
                                   head_dim=cfg.resolved_head_dim)
        x = x + L.mlp_apply(pl["mlp"], L.layernorm(pl["ln2"], x),
                            act="gelu", gated=False)
        return x, (k_l, v_l)

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.layernorm(params["ln_f"], x)
    logits = L.unembed(params["embed"], x)
    return logits[:, 0], dict(cache, k=k, v=v)
