"""GQA attention: blockwise (flash-style) training/prefill path + decode path.

Training/prefill uses a two-level blockwise softmax: an outer ``lax.scan`` over
query blocks and an inner ``lax.fori_loop`` over KV blocks with *dynamic*
bounds derived from causality and the sliding window — so local-attention
layers (gemma3) and causal masking skip entire KV blocks instead of masking
wasted FLOPs. Online-softmax carries (m, l, acc) in f32.

Layouts: activations (B, S, H, Dh); KV caches (B, S_max, KV, Dh) so decode
appends with a single dynamic_update_slice on axis 1.

``window`` may be a *traced* per-layer scalar (scan-over-layers passes the
layer's window in as data): 0 means global causal attention.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import constrain

NEG_INF = -1e30


def attn_init(key, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, dtype, qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": L.dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": L.dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": L.dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(params, x, num_heads, num_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, num_heads, head_dim)
    k = k.reshape(B, S, num_kv_heads, head_dim)
    v = v.reshape(B, S, num_kv_heads, head_dim)
    return q, k, v


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    window=0,
                    q_offset: int = 0,
                    block_q: int = 512,
                    block_kv: int = 1024,
                    scale: Optional[float] = None,
                    differentiable: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, Dh); k, v: (B, Skv, KV, Dh) -> (B, Sq, H, Dh).

    ``window`` 0 = unbounded; >0 = attend only to the last ``window`` keys
    (inclusive of self). May be traced.

    ``differentiable=True`` (training) dispatches to the custom-VJP flash
    implementation in ``repro.models.flash`` (recompute-based backward,
    O(S*Dh) activation memory). Inference paths keep the block-skipping
    dynamic-bound loop below.
    """
    if differentiable:
        from repro.models.flash import flash_attention_trainable
        return flash_attention_trainable(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=block_q, block_kv=block_kv, scale=scale)
    B, Sq, H, Dh = q.shape
    _, Skv, KV, _ = k.shape
    Dv = v.shape[-1]                                    # may differ (MLA)
    rep = H // KV
    scale = scale or (1.0 / math.sqrt(Dh))

    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    pq = (-Sq) % bq
    pkv = (-Skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    nq = (Sq + pq) // bq
    nkv = (Skv + pkv) // bkv

    qf = q.astype(jnp.float32) * scale
    # (nq, B, bq, KV, rep, Dh)
    qb = qf.reshape(B, nq, bq, KV, rep, Dh).transpose(1, 0, 2, 3, 4, 5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    window_t = jnp.asarray(window, jnp.int32)

    def q_block(carry, inp):
        qblk, qi = inp                                  # (B, bq, KV, rep, Dh)
        q_start = q_offset + qi * bq
        q_pos = q_start + jnp.arange(bq)                # (bq,)

        if causal:
            kv_hi = jnp.minimum((q_start + bq + bkv - 1) // bkv, nkv)
        else:
            kv_hi = jnp.asarray(nkv, jnp.int32)
        kv_lo = jnp.where(window_t > 0,
                          jnp.maximum((q_start - window_t + 1) // bkv, 0), 0)
        kv_lo = jnp.where(causal | (window_t > 0), kv_lo, 0).astype(jnp.int32)

        def body(t, st):
            m, l, acc = st
            kblk = jax.lax.dynamic_slice_in_dim(kf, t * bkv, bkv, axis=1)
            vblk = jax.lax.dynamic_slice_in_dim(vf, t * bkv, bkv, axis=1)
            # scores: (B, KV, rep, bq, bkv)
            s = jnp.einsum("bqkrd,bjkd->bkrqj", qblk, kblk)
            k_pos = t * bkv + jnp.arange(bkv)
            mask = jnp.ones((bq, bkv), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            mask &= k_pos[None, :] < Skv                 # padded keys
            wmask = (q_pos[:, None] - k_pos[None, :]) < window_t
            mask &= jnp.where(window_t > 0, wmask, True)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # explicit mask multiply: fully-masked blocks (m_new still
            # NEG_INF) must contribute 0, not exp(0)
            p = jnp.exp(s - m_new[..., None]) * mask.astype(jnp.float32)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkrqj,bjkd->bkrqd", p, vblk)
            acc_new = acc * corr[..., None] + pv
            return m_new, l_new, acc_new

        m0 = jnp.full((B, KV, rep, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, bq, Dv), jnp.float32)
        m, l, acc = jax.lax.fori_loop(kv_lo, kv_hi, body, (m0, l0, a0))
        out = acc / jnp.maximum(l[..., None], 1e-30)     # (B, KV, rep, bq, Dh)
        out = out.transpose(0, 3, 1, 2, 4)               # (B, bq, KV, rep, Dh)
        return carry, out

    _, outs = jax.lax.scan(q_block, 0, (qb, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * bq, H, Dv)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q: jnp.ndarray, cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     pos, *, window=0,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token attention. q: (B, H, Dh); caches: (B, S_max, KV, Dh);
    pos: () or (B,) current position (number of valid tokens = pos + 1)."""
    B, H, Dh = q.shape
    _, Smax, KV, _ = cache_k.shape
    rep = H // KV
    scale = scale or (1.0 / math.sqrt(Dh))
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    qf = q.astype(jnp.float32).reshape(B, KV, rep, Dh) * scale
    s = jnp.einsum("bkrd,bjkd->bkrj", qf, cache_k.astype(jnp.float32))
    idx = jnp.arange(Smax)
    mask = idx[None, :] <= pos[:, None]                  # (B, Smax)
    window_t = jnp.asarray(window, jnp.int32)
    wmask = (pos[:, None] - idx[None, :]) < window_t
    mask &= jnp.where(window_t > 0, wmask, True)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrj,bjkd->bkrd", p, cache_v.astype(jnp.float32))
    return out.reshape(B, H, Dh).astype(q.dtype)


# ------------------------------------------------------------- module API --

def self_attn_apply(params, x, *, num_heads, num_kv_heads, head_dim,
                    theta, window=0, q_offset: int = 0,
                    positions: Optional[jnp.ndarray] = None,
                    differentiable: bool = False) -> jnp.ndarray:
    """Full-sequence causal self attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, num_heads, num_kv_heads, head_dim)
    if positions is None:
        positions = q_offset + jnp.arange(S)[None, :]
    q = L.apply_rope(q, positions, theta)
    k = L.apply_rope(k, positions, theta)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_offset=q_offset, differentiable=differentiable)
    out = out.reshape(B, S, num_heads * head_dim)
    return out @ params["wo"], (k, v)


def self_attn_decode(params, x, cache_k, cache_v, pos, *, num_heads,
                     num_kv_heads, head_dim, theta, window=0):
    """x: (B, 1, d). Returns (out (B, 1, d), new_cache_k, new_cache_v)."""
    B = x.shape[0]
    q, k, v = _project_qkv(params, x, num_heads, num_kv_heads, head_dim)
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B, 1))
    q = L.apply_rope(q, posv, theta)
    k = L.apply_rope(k, posv, theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                                  pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                                  pos, axis=1)
    out = decode_attention(q[:, 0], cache_k, cache_v, pos, window=window)
    out = out.reshape(B, 1, num_heads * head_dim)
    return out @ params["wo"], cache_k, cache_v


def cross_attn_apply(params, x, kv_k, kv_v, *, num_heads, num_kv_heads,
                     head_dim, differentiable: bool = False) -> jnp.ndarray:
    """Non-causal cross attention against precomputed K/V (B, S_kv, KV, Dh)."""
    B, S, _ = x.shape
    q = (x @ params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, S, num_heads, head_dim)
    out = flash_attention(q, kv_k, kv_v, causal=False, window=0,
                          differentiable=differentiable)
    out = out.reshape(B, S, num_heads * head_dim)
    return out @ params["wo"]


def cross_kv(params, src, *, num_kv_heads, head_dim):
    """Project encoder/image features to cross-attention K/V once."""
    B, S, _ = src.shape
    k = src @ params["wk"]
    v = src @ params["wv"]
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    return (k.reshape(B, S, num_kv_heads, head_dim),
            v.reshape(B, S, num_kv_heads, head_dim))
