"""Shared building blocks: norms, RoPE, MLPs, initializers.

Parameters are plain nested dicts of jnp arrays (no framework dependency);
every apply function is pure. Compute dtype is bf16 by default with f32 norms
and f32 logits, matching production LM practice.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# ----------------------------------------------------------------- RoPE ----

def rope_freqs(head_dim: int, theta) -> jnp.ndarray:
    """Inverse frequencies; theta may be a traced scalar (per-layer pattern)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / jnp.power(theta, exponents)                     # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim), positions: (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv         # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLP ----

def mlp_init(key, d_model: int, d_ff: int, dtype, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d_model, d_ff, dtype),
         "w_down": dense_init(k2, d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def mlp_apply(params, x: jnp.ndarray, act: str = "silu",
              gated: bool = True) -> jnp.ndarray:
    from repro.models.sharding import constrain
    up = x @ params["w_up"]
    if gated:
        gate = x @ params["w_gate"]
        h = jax.nn.silu(gate) * up if act == "silu" else jax.nn.gelu(gate) * up
    else:
        h = jax.nn.gelu(up) if act == "gelu" else jax.nn.silu(up)
    h = constrain(h, "batch", None, "model")
    return h @ params["w_down"]


def unembed(embed: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Tied unembedding -> f32 logits."""
    return (x.astype(jnp.float32) @ embed.astype(jnp.float32).T)
