"""Loop-aware cost extraction from post-optimization HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE, regardless of
trip count (verified: a 10-iteration scan of a matmul reports 1 matmul of
flops). Since this framework scans over layers / microbatches / attention
blocks, that undercounts flops, HBM traffic and collective bytes by 1-2
orders of magnitude. This module re-derives the three roofline inputs from
the HLO text itself, multiplying each while body by its trip count:

  * flops: every `dot(...)` — 2 * prod(result_shape) * prod(contracting dims)
  * traffic: per top-level instruction, result bytes + operand bytes
    (post-fusion granularity — each non-fused instruction materializes once;
    fused-computation internals are excluded, the fusion boundary counts)
  * collectives: all-reduce (x2 for ring) / all-gather / reduce-scatter /
    all-to-all / collective-permute result bytes

Trip counts come from the loop condition: XLA emits `compare(induction,
constant(N)), direction=LT` (possibly wrapped in a fusion whose operand is
the constant); induction starts at 0 and steps 1 for scan-derived loops, so
the s32 constant IS the trip count. Unrecognized conditions fall back to a
caller-provided hint (recorded in the result).

All numbers are per-chip: the HLO module is the post-GSPMD per-shard program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_NO_TRAFFIC_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id"}

# elementwise/VPU arithmetic: 1 flop per output element (so ℓ1-style
# abs/subtract reductions are visible to the compute term, not just matmuls)
_ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "abs", "negate", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "maximum",
    "minimum", "power", "rsqrt", "sqrt", "sine", "cosine", "select",
    "logistic", "atan2", "clamp", "round-nearest-afz", "floor", "ceil",
}
_REDUCE_OPS = {"reduce", "reduce-window"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([^=]+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")


def _shape_dims(shape_str: str):
    """[(dtype, [dims...]), ...] for a type string (handles tuples)."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dtype, d))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rest: str          # operands + attributes (raw tail of the line)
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]
    shapes: Dict[str, str]   # instr name -> result type string


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw).rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = Computation(name=hdr.group(2), is_entry=bool(hdr.group(1)),
                              instrs=[], shapes={})
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        # operands: %refs before the first "), " attribute boundary
        operands = re.findall(r"%([\w.\-]+)", rest.split("), ")[0])
        cur.instrs.append(Instr(name=name, result_type=rtype.strip(), op=op,
                                rest=rest, operands=operands))
        cur.shapes[name] = rtype.strip()
    return comps


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    """2 * prod(result) * prod(lhs contracting dims)."""
    res = _shape_dims(ins.result_type)
    if not res:
        return 0.0
    n_out = 1
    for d in res[0][1]:
        n_out *= d
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    if not mc or not ins.operands:
        return 2.0 * n_out  # dot with no contraction info: treat K=1
    lhs_type = shapes.get(ins.operands[0], "")
    lhs = _shape_dims(lhs_type)
    if not lhs:
        return 2.0 * n_out
    k = 1
    dims = lhs[0][1]
    for ci in mc.group(1).split(","):
        if ci != "" and int(ci) < len(dims):
            k *= dims[int(ci)]
    return 2.0 * n_out * k


def _trip_count(cond: Computation, default: float) -> float:
    """Largest s32 constant in the condition computation (scan loops compare
    the 0-based induction var LT trip_count)."""
    best = None
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.match(r"^\s*constant\((-?\d+)\)", ins.op + "(" + ins.rest)
            mm = re.search(r"constant\((-?\d+)\)", "%s(%s" % (ins.op, ins.rest))
            if mm and ins.result_type.startswith("s32"):
                v = int(mm.group(1))
                if best is None or v > best:
                    best = v
    return float(best) if best and best > 0 else default


@dataclasses.dataclass
class HloCost:
    flops: float            # total (dot + elementwise)
    dot_flops: float        # MXU-eligible
    elem_flops: float       # VPU (elementwise + reduces)
    traffic_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]
    unknown_while: int      # loops whose trip count fell back to the hint


def analyze(text: str, while_hint: float = 1.0) -> HloCost:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCost(0.0, 0.0, 0.0, 0.0, 0.0, {}, 0)

    # computations referenced as fusion bodies / reducers: no traffic of
    # their own (counted at the boundary), but dots inside still count flops.
    fused_bodies = set()
    for c in comps.values():
        for ins in c.instrs:
            for attr, names in re.findall(r"(calls|to_apply)=%([\w.\-]+)",
                                          ins.rest):
                fused_bodies.add(names)

    state = {"unknown_while": 0}
    coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    visited_stack: List[str] = []

    def comp_cost(c: Computation, mult: float, traffic_on: bool):
        flops = 0.0      # dot flops
        eflops = 0.0     # elementwise flops
        traffic = 0.0
        if c.name in visited_stack:       # defensive: no recursion
            return 0.0, 0.0, 0.0
        visited_stack.append(c.name)
        for ins in c.instrs:
            if ins.op == "dot":
                flops += _dot_flops(ins, c.shapes) * mult
            elif ins.op in _ELEMENTWISE_OPS:
                res = _shape_dims(ins.result_type)
                if res:
                    ne = 1
                    for dd in res[0][1]:
                        ne *= dd
                    eflops += float(ne) * mult
            elif ins.op in _REDUCE_OPS:
                if ins.operands and ins.operands[0] in c.shapes:
                    eflops += _shape_bytes(c.shapes[ins.operands[0]]) / 4.0 * mult
            elif ins.op == "while":
                body_m = re.search(r"body=%([\w.\-]+)", ins.rest)
                cond_m = re.search(r"condition=%([\w.\-]+)", ins.rest)
                trip = while_hint
                if cond_m and cond_m.group(1) in comps:
                    t = _trip_count(comps[cond_m.group(1)], -1.0)
                    if t > 0:
                        trip = t
                    else:
                        state["unknown_while"] += 1
                if body_m and body_m.group(1) in comps:
                    f2, e2, t2 = comp_cost(comps[body_m.group(1)], mult * trip,
                                           traffic_on)
                    flops += f2
                    eflops += e2
                    traffic += t2
            elif ins.op in ("fusion", "call", "custom-call", "async-start"):
                for _, cname in re.findall(r"(calls|to_apply)=%([\w.\-]+)",
                                           ins.rest):
                    if cname in comps:
                        f2, e2, _ = comp_cost(comps[cname], mult, False)
                        flops += f2
                        eflops += e2
            elif ins.op == "conditional":
                for cname in re.findall(r"branch_computations=\{([^}]*)\}",
                                        ins.rest):
                    subs = re.findall(r"%([\w.\-]+)", cname)
                    branch_costs = [comp_cost(comps[s], mult, traffic_on)
                                    for s in subs if s in comps]
                    if branch_costs:
                        flops += max(b[0] for b in branch_costs)
                        eflops += max(b[1] for b in branch_costs)
                        traffic += max(b[2] for b in branch_costs)

            kind = None
            for k in _COLLECTIVES:
                if ins.op == k or ins.op.startswith(k + "-"):
                    kind = k
                    break
            if kind:
                b = _shape_bytes(ins.result_type) * mult
                if kind == "all-reduce":
                    b *= 2.0            # ring: reduce-scatter + all-gather
                coll[kind] += b

            if traffic_on and ins.op not in _NO_TRAFFIC_OPS:
                t = _shape_bytes(ins.result_type)
                for o in ins.operands:
                    if o in c.shapes:
                        t += _shape_bytes(c.shapes[o])
                traffic += t * mult
        visited_stack.pop()
        return flops, eflops, traffic

    flops, eflops, traffic = comp_cost(entry, 1.0, True)
    return HloCost(flops=flops + eflops, dot_flops=flops, elem_flops=eflops,
                   traffic_bytes=traffic,
                   collective_bytes=sum(coll.values()),
                   collective_by_kind=coll,
                   unknown_while=state["unknown_while"])
