"""Three-term roofline from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective bytes are
NOT in cost_analysis: we parse the post-optimization HLO text and sum the
result-shape bytes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute (all-reduce counted twice: reduce + broadcast
phases on a ring). Per-chip bytes: GSPMD HLO shapes are already per-shard.

Hardware model (TPU v5e-like, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (45 GB/s effective used for the collective term with 4
links usable per chip in a 2D torus — we report the conservative 1-link
number; the table notes both).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 MXU per chip
VPU_FLOPS = 2e12             # f32 elementwise (VPU) per chip, approx
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[dims]' or a tuple '(a, b, ...)' result string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: int
    count_by_kind: dict


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by_kind = {k: 0 for k in _COLLECTIVES}
    count_by_kind = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # e.g.:  %all-reduce.5 = bf16[1024,512]{1,0} all-reduce(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        kind = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):
                kind = k
                break
        if kind is None:
            continue
        b = _shape_bytes(shape_str)
        # ring all-reduce moves ~2x the payload (reduce-scatter + all-gather)
        if kind == "all-reduce":
            b *= 2
        bytes_by_kind[kind] += b
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind=bytes_by_kind,
                           total_bytes=sum(bytes_by_kind.values()),
                           count_by_kind=count_by_kind)


@dataclasses.dataclass
class Roofline:
    """All byte/flop figures are PER CHIP: ``compiled.cost_analysis()`` and
    the HLO text both describe the post-GSPMD per-shard program (verified by
    calibration against an analytic matmul — see EXPERIMENTS.md §Dry-run)."""
    flops: float                 # per-chip HLO flops (dot + elementwise)
    hbm_bytes: float             # per-chip bytes accessed
    collective_bytes: float      # per-chip collective bytes
    chips: int
    model_flops: float = 0.0     # analytic 6*N*D (or 6*N_active*D), ALL chips
    dot_flops: float = 0.0       # MXU-eligible portion
    elem_flops: float = 0.0      # VPU portion

    @property
    def t_compute(self) -> float:
        if self.dot_flops or self.elem_flops:
            return self.dot_flops / PEAK_FLOPS + self.elem_flops / VPU_FLOPS
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline-optimistic step time: max of the three terms (perfect
        overlap of compute, HBM and ICI)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> Optional[float]:
        if not self.model_flops:
            return None
        return self.model_flops / max(self.flops * self.chips, 1.0)

    @property
    def mfu(self) -> Optional[float]:
        """Model-FLOPs utilization at the roofline-optimistic step time."""
        if not self.model_flops:
            return None
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / \
            max(self.step_time, 1e-30)

    def row(self) -> dict:
        out = {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck, "step_time_s": self.step_time,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac, "mfu": self.mfu,
        }
        out["dot_flops"] = self.dot_flops
        out["elem_flops"] = self.elem_flops
        for k in ("traffic_upper", "xla_flops", "xla_bytes", "unknown_while"):
            if hasattr(self, k):
                out[k] = getattr(self, k)
        return out


def count_params(params_shape) -> int:
    import jax
    return sum(int(pyleaf.size) for pyleaf in jax.tree.leaves(params_shape))


def model_flops_train(num_params: int, tokens: int,
                      active_frac: float = 1.0) -> float:
    """6*N*D for a train step (fwd+bwd)."""
    return 6.0 * num_params * active_frac * tokens


def model_flops_decode(num_params: int, batch: int,
                       active_frac: float = 1.0) -> float:
    """2*N per generated token (one fwd)."""
    return 2.0 * num_params * active_frac * batch


def from_compiled(compiled, *, chips: int, model_flops: float = 0.0,
                  hlo_text: Optional[str] = None,
                  while_hint: float = 1.0) -> Roofline:
    """Build the roofline from the compiled artifact.

    flops / collective bytes: loop-aware HLO cost model (hlo_cost) — XLA's
    own cost_analysis() counts while bodies once, undercounting scanned
    programs by their trip counts (verified empirically).

    memory term: per-chip LIVE bytes (arguments + outputs + temps from
    memory_analysis) — the bytes a perfectly-fused step streams at least
    once. The instruction-granularity traffic estimate from the CPU-backend
    HLO is kept as ``traffic_upper`` (CPU fuses far less than the TPU
    backend would, so it overestimates; the truth lies between).
    """
    from repro.roofline import hlo_cost
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = hlo_cost.analyze(text, while_hint=while_hint)
    mem = compiled.memory_analysis()
    live = float(mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    r = Roofline(flops=hc.flops, hbm_bytes=live,
                 collective_bytes=hc.collective_bytes,
                 chips=chips, model_flops=model_flops,
                 dot_flops=hc.dot_flops, elem_flops=hc.elem_flops)
    r.traffic_upper = hc.traffic_bytes
    r.xla_flops = float(cost.get("flops", 0.0))
    r.xla_bytes = float(cost.get("bytes accessed", 0.0))
    r.unknown_while = hc.unknown_while
    return r
