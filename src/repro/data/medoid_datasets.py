"""Synthetic datasets with the statistics of the paper's benchmarks.

The paper evaluates on RNA-Seq (simplex rows, ℓ1), Netflix (sparse ratings,
cosine) and MNIST-zeros (dense images, ℓ2). The property that makes
correlated sampling win on those datasets is *reference heterogeneity*: a
reference point x_J contributes a shared "remoteness" term β_J to every
distance d(x_i, x_J) (Appendix B's additive model), which cancels in
d(x_1,x_J) − d(x_i,x_J). We synthesize lookalikes that carry this structure
explicitly (per-point lognormal spread / Dirichlet concentration / noise
level), calibrated so ρ_near ≈ 0.05–0.3 and H2/H̃2 ≈ 3–50, bracketing the
paper's measured 4.8 (MNIST) and 6.6 (RNA-Seq 20k).

``planted_medoid`` keeps controllable Δ gaps for property tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rnaseq_like(key, n: int, d: int = 4096, radial: float = 1.5,
                sparsity: float = 0.3) -> jnp.ndarray:
    """Probability-simplex rows (ℓ1): Dirichlet with per-point concentration.

    Low-concentration rows are spiky and ℓ1-far from everything (large β_j);
    high-concentration rows sit near the base measure (candidate medoids).
    Measured on this generator: rho_near ~ 0.23, variance reduction ~ 38x —
    matching the paper's Fig 3(b) (rho = 0.25 on RNA-Seq 20k).
    """
    kb, ka, kg, ks = jax.random.split(key, 4)
    base = jax.random.gamma(kb, 0.3, (d,)) + 1e-3
    base = base / base.sum()
    alpha_pt = jnp.exp(jax.random.normal(ka, (n,)) * radial - 1.0)  # lognormal
    g = jax.random.gamma(kg, jnp.maximum(alpha_pt[:, None] * base[None, :] * d,
                                         1e-3))
    mask = jax.random.bernoulli(ks, 1.0 - sparsity, (n, d))
    g = g * mask + 1e-6
    return g / g.sum(axis=1, keepdims=True)


def netflix_like(key, n: int, d: int = 2048, radial: float = 1.2
                 ) -> jnp.ndarray:
    """Sparse nonnegative 'ratings' (cosine): a dominant taste direction with
    per-user angular spread, plus Zipf item popularity x per-user activity
    driving the (correlated) sparsity pattern — β_j here is the reference
    user's angle/activity. Measured: ~8% density, rho_near ~ 0.32."""
    ku, kn, ke, ks, ka = jax.random.split(key, 5)
    u0 = jax.nn.relu(jax.random.normal(ku, (1, d))) + 0.1
    r = jnp.exp(jax.random.normal(ke, (n,)) * radial) * 0.5
    vals = jax.nn.relu(u0 + r[:, None] * jax.random.normal(kn, (n, d)))
    pop = 1.0 / (1.0 + jnp.arange(d) * 0.05)             # item popularity
    act = jnp.exp(jax.random.normal(ka, (n,)) * radial)  # user activity
    p = jnp.clip(pop[None, :] * act[:, None] * 0.5, 0.0, 1.0)
    x = vals * jax.random.bernoulli(ks, p)
    # guard all-zero rows (cosine undefined): give them one tiny coordinate
    return x.at[:, 0].add(1e-3)


def mnist_zeros_like(key, n: int, d: int = 784, radial: float = 0.4
                     ) -> jnp.ndarray:
    """Dense one-cluster images (ℓ2): prototype + lognormal per-image spread."""
    kb, kn, kr = jax.random.split(key, 3)
    proto = jax.nn.sigmoid(jax.random.normal(kb, (1, d)) * 2.0)
    r = jnp.exp(jax.random.normal(kr, (n,)) * radial) * 0.25
    return jnp.clip(proto + r[:, None] * jax.random.normal(kn, (n, d)),
                    0.0, 1.0)


def planted_medoid(key, n: int, d: int = 64, gap: float = 0.5) -> jnp.ndarray:
    """Gaussian cloud + one point pulled toward the centroid: index 0 is the
    medoid with controllable margin (for property tests)."""
    kx, _ = jax.random.split(key)
    x = jax.random.normal(kx, (n, d))
    centroid = jnp.mean(x, axis=0)
    x = x.at[0].set(centroid * (1.0 - gap * 0.1))
    return x


DATASETS = {
    "rnaseq20k_like": ("l1", rnaseq_like),
    "netflix20k_like": ("cosine", netflix_like),
    "mnist_zeros_like": ("l2", mnist_zeros_like),
}
