"""Synthetic datasets with the statistics of the paper's benchmarks.

The paper evaluates on RNA-Seq (simplex rows, ℓ1), Netflix (sparse ratings,
cosine) and MNIST-zeros (dense images, ℓ2). The property that makes
correlated sampling win on those datasets is *reference heterogeneity*: a
reference point x_J contributes a shared "remoteness" term β_J to every
distance d(x_i, x_J) (Appendix B's additive model), which cancels in
d(x_1,x_J) − d(x_i,x_J). We synthesize lookalikes that carry this structure
explicitly (per-point lognormal spread / Dirichlet concentration / noise
level), calibrated so ρ_near ≈ 0.05–0.3 and H2/H̃2 ≈ 3–50, bracketing the
paper's measured 4.8 (MNIST) and 6.6 (RNA-Seq 20k).

``planted_medoid`` keeps controllable Δ gaps for property tests.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def rnaseq_like(key, n: int, d: int = 4096, radial: float = 1.5,
                sparsity: float = 0.3) -> jnp.ndarray:
    """Probability-simplex rows (ℓ1): Dirichlet with per-point concentration.

    Low-concentration rows are spiky and ℓ1-far from everything (large β_j);
    high-concentration rows sit near the base measure (candidate medoids).
    Measured on this generator: rho_near ~ 0.23, variance reduction ~ 38x —
    matching the paper's Fig 3(b) (rho = 0.25 on RNA-Seq 20k).
    """
    kb, ka, kg, ks = jax.random.split(key, 4)
    base = jax.random.gamma(kb, 0.3, (d,)) + 1e-3
    base = base / base.sum()
    alpha_pt = jnp.exp(jax.random.normal(ka, (n,)) * radial - 1.0)  # lognormal
    g = jax.random.gamma(kg, jnp.maximum(alpha_pt[:, None] * base[None, :] * d,
                                         1e-3))
    mask = jax.random.bernoulli(ks, 1.0 - sparsity, (n, d))
    g = g * mask + 1e-6
    return g / g.sum(axis=1, keepdims=True)


def netflix_like(key, n: int, d: int = 2048, radial: float = 1.2
                 ) -> jnp.ndarray:
    """Sparse nonnegative 'ratings' (cosine): a dominant taste direction with
    per-user angular spread, plus Zipf item popularity x per-user activity
    driving the (correlated) sparsity pattern — β_j here is the reference
    user's angle/activity. Measured: ~8% density, rho_near ~ 0.32."""
    ku, kn, ke, ks, ka = jax.random.split(key, 5)
    u0 = jax.nn.relu(jax.random.normal(ku, (1, d))) + 0.1
    r = jnp.exp(jax.random.normal(ke, (n,)) * radial) * 0.5
    vals = jax.nn.relu(u0 + r[:, None] * jax.random.normal(kn, (n, d)))
    pop = 1.0 / (1.0 + jnp.arange(d) * 0.05)             # item popularity
    act = jnp.exp(jax.random.normal(ka, (n,)) * radial)  # user activity
    p = jnp.clip(pop[None, :] * act[:, None] * 0.5, 0.0, 1.0)
    x = vals * jax.random.bernoulli(ks, p)
    # guard all-zero rows (cosine undefined): give them one tiny coordinate
    return x.at[:, 0].add(1e-3)


def mnist_zeros_like(key, n: int, d: int = 784, radial: float = 0.4
                     ) -> jnp.ndarray:
    """Dense one-cluster images (ℓ2): prototype + lognormal per-image spread."""
    kb, kn, kr = jax.random.split(key, 3)
    proto = jax.nn.sigmoid(jax.random.normal(kb, (1, d)) * 2.0)
    r = jnp.exp(jax.random.normal(kr, (n,)) * radial) * 0.25
    return jnp.clip(proto + r[:, None] * jax.random.normal(kn, (n, d)),
                    0.0, 1.0)


def planted_medoid(key, n: int, d: int = 64, gap: float = 0.5) -> jnp.ndarray:
    """Gaussian cloud + one point pulled toward the centroid: index 0 is the
    medoid with controllable margin (for property tests)."""
    kx, _ = jax.random.split(key)
    x = jax.random.normal(kx, (n, d))
    centroid = jnp.mean(x, axis=0)
    x = x.at[0].set(centroid * (1.0 - gap * 0.1))
    return x


DATASETS = {
    "rnaseq20k_like": ("l1", rnaseq_like),
    "netflix20k_like": ("cosine", netflix_like),
    "mnist_zeros_like": ("l2", mnist_zeros_like),
}


# ---------------------------------------------------------------------------
# planted-cluster variants (the k-medoids workload): same per-metric structure
# as the single-medoid generators, but with k planted groups and ground-truth
# labels. Cluster sizes are deliberately UNEVEN (log-spaced) so the per-cluster
# subproblems span several power-of-two buckets — the ragged engine's traffic.
# ---------------------------------------------------------------------------

def uneven_sizes(n: int, k: int, spread: float = 2.0) -> list[int]:
    """k log-spaced cluster sizes summing to n (largest ~ e^spread x the
    smallest) — heterogeneous on purpose, to exercise bucketed dispatch."""
    if not 1 <= k <= n:
        raise ValueError(f"need 1 <= k <= n, got k={k}, n={n}")
    w = [math.exp(spread * i / max(1, k - 1)) for i in range(k)]
    sizes = [max(1, int(n * wi / sum(w))) for wi in w]
    diff = n - sum(sizes)      # clamping can overshoot either way
    if diff > 0:
        sizes[-1] += diff
    i = k - 1
    while diff < 0:            # shrink from the largest, never below 1
        take = min(sizes[i] - 1, -diff)
        sizes[i] -= take
        diff += take
        i -= 1
    return sizes


def _labels(sizes) -> jnp.ndarray:
    return jnp.concatenate([jnp.full((s,), c, jnp.int32)
                            for c, s in enumerate(sizes)])


def planted_clusters(key, n: int, d: int = 64, k: int = 8, gap: float = 4.0,
                     spread: float = 2.0):
    """k well-separated Gaussian blobs (ℓ2), uneven sizes; returns
    ``(data (n, d), labels (n,))``. ``gap`` scales the center separation
    relative to the unit within-cluster noise."""
    sizes = uneven_sizes(n, k, spread)
    kc, kx = jax.random.split(key)
    centers = gap * jax.random.normal(kc, (k, d))
    labels = _labels(sizes)
    return centers[labels] + jax.random.normal(kx, (n, d)), labels


def rnaseq_clusters(key, n: int, d: int = 1024, k: int = 8,
                    concentration: float = 80.0, spread: float = 2.0):
    """Simplex rows (ℓ1) with k planted expression programs: each cluster's
    Dirichlet base measure concentrates on its own coordinate block (plus a
    small shared background), so between-cluster ℓ1 is near the maximal 2
    while within-cluster rows stay near their base."""
    sizes = uneven_sizes(n, k, spread)
    labels = _labels(sizes)
    kb, kg, kw = jax.random.split(key, 3)
    blk = d // k
    base = jax.random.gamma(kb, 0.5, (k, d)) * 0.02 + 1e-4   # background
    block_mask = (jnp.arange(d)[None, :] // blk) == jnp.arange(k)[:, None]
    base = base + block_mask * (jax.random.gamma(kw, 2.0, (k, d)) + 0.5)
    base = base / base.sum(axis=1, keepdims=True)            # (k, d) simplex
    alpha = concentration * base[labels] * d / k
    g = jax.random.gamma(kg, jnp.maximum(alpha, 1e-3)) + 1e-8
    return g / g.sum(axis=1, keepdims=True), labels


def netflix_clusters(key, n: int, d: int = 512, k: int = 8,
                     noise: float = 0.25, spread: float = 2.0):
    """Sparse nonnegative ratings (cosine) with k taste communities: each
    cluster rides its own (near-orthogonal in high d) taste direction, with
    per-user noise and popularity-driven sparsity."""
    sizes = uneven_sizes(n, k, spread)
    labels = _labels(sizes)
    ku, kn, ks = jax.random.split(key, 3)
    tastes = jax.nn.relu(jax.random.normal(ku, (k, d))) + 0.05
    vals = jax.nn.relu(tastes[labels]
                       + noise * jax.random.normal(kn, (n, d)))
    pop = 1.0 / (1.0 + jnp.arange(d) * 0.02)
    x = vals * jax.random.bernoulli(ks, jnp.clip(pop, 0.05, 1.0), (n, d))
    return x.at[:, 0].add(1e-3), labels     # guard all-zero rows


def mnist_clusters(key, n: int, d: int = 784, k: int = 8,
                   noise: float = 0.15, spread: float = 2.0):
    """Dense images (ℓ2): k digit prototypes + small per-image noise."""
    sizes = uneven_sizes(n, k, spread)
    labels = _labels(sizes)
    kp, kn = jax.random.split(key)
    protos = jax.nn.sigmoid(jax.random.normal(kp, (k, d)) * 2.0)
    x = jnp.clip(protos[labels] + noise * jax.random.normal(kn, (n, d)),
                 0.0, 1.0)
    return x, labels


# name -> (metric, generator(key, n, d, k) -> (data, labels))
CLUSTER_DATASETS = {
    "planted": ("l2", planted_clusters),
    "rnaseq_like": ("l1", rnaseq_clusters),
    "netflix_like": ("cosine", netflix_clusters),
    "mnist_like": ("l2", mnist_clusters),
}
