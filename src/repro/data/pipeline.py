"""Deterministic, shardable synthetic data pipeline.

Production properties implemented here:
  * stateless indexing: batch t is a pure function of (seed, t) -> restart at
    any step reproduces the exact stream (checkpoint stores only `step`);
  * per-host sharding: each data-parallel rank draws its own slice of the
    global batch from disjoint PRNG streams (no host exchange);
  * modality stubs (audio frames / image embeddings) ride along per config.

The generator synthesizes Zipf-ish token streams with local n-gram structure
so cross-entropy actually *decreases* during the integration tests (uniform
random tokens would pin the loss at log V).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelCfg


@dataclasses.dataclass(frozen=True)
class DataCfg:
    seed: int = 0
    dp_rank: int = 0
    dp_size: int = 1


def batch_at(cfg: ModelCfg, shape: InputShape, step: int,
             data: DataCfg = DataCfg()) -> dict:
    """The global batch for `step`, restricted to this rank's slice."""
    assert shape.global_batch % data.dp_size == 0
    local_b = shape.global_batch // data.dp_size
    key = jax.random.fold_in(jax.random.key(data.seed), step)
    key = jax.random.fold_in(key, data.dp_rank)
    kt, km, kf = jax.random.split(key, 3)

    V = cfg.vocab_size
    # Zipf-ish marginal + first-order structure: token ~ f(prev) with noise
    base = jax.random.categorical(
        kt, _zipf_logits(V), shape=(local_b, shape.seq_len))
    prev = jnp.roll(base, 1, axis=1)
    mix = jax.random.bernoulli(km, 0.5, base.shape)
    tokens = jnp.where(mix, (prev * 31 + 7) % V, base).astype(jnp.int32)
    batch = {"tokens": tokens}

    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            kf, (local_b, cfg.num_audio_frames, cfg.d_model), dt)
    if cfg.family == "vlm":
        batch["image_embed"] = jax.random.normal(
            kf, (local_b, cfg.num_image_tokens, cfg.d_model), dt)
    return batch


def _zipf_logits(v: int) -> jnp.ndarray:
    ranks = jnp.arange(1, v + 1, dtype=jnp.float32)
    return -1.1 * jnp.log(ranks)


def stream(cfg: ModelCfg, shape: InputShape, start_step: int = 0,
           data: DataCfg = DataCfg()) -> Iterator[dict]:
    """Resumable iterator: `stream(..., start_step=k)` skips to batch k with
    O(1) work (stateless indexing — the fault-tolerance hook)."""
    t = start_step
    while True:
        yield batch_at(cfg, shape, t, data)
        t += 1
