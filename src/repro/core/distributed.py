"""Distributed Correlated Sequential Halving via shard_map.

Dataset layout: rows sharded over the flattened mesh (every axis participates:
on the production mesh that is pod x data x model = 512-way row sharding).
Each round of corrSH becomes:

  1. reference *indices* for the round are computed from a replicated key —
     identical on every device (this IS the paper's correlation trick: one
     shared reference set for all surviving arms, here realized with zero
     communication because indices are derived, not exchanged);
  2. reference *rows* (t_r, d) are materialized everywhere with a
     masked-scatter + psum (an all-gather of unaligned rows);
  3. each device computes centrality partial-sums for its *candidate* slice
     (s_r / P candidates x t_r references) — compute is sharded on the
     candidate axis so the (s_r,) estimates come out locally;
  4. estimates are all-gathered ((s_r,) floats — tiny) and the halving top-k
     runs replicated.

Communication per round: one psum of (t_r, d) + one all-gather of (s_r,).
Compute per device: s_r * t_r / P distance evaluations — perfect scaling.

All shapes are static (see corr_sh.round_schedule), so the entire multi-round
algorithm lowers to a single XLA program under shard_map + jit.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.backend import get_backend
from repro.engine import default_select, round_schedule

try:
    # jax >= 0.6: shard_map is a public API and the replication check is
    # spelled check_vma.
    shard_map = functools.partial(jax.shard_map, check_vma=False)
except AttributeError:
    # jax 0.4/0.5: experimental module, check_rep spelling. Outputs are
    # replicated via psum/all_gather either way, so the check is off.
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    shard_map = functools.partial(_experimental_shard_map, check_rep=False)


def _gather_rows(x_local: jnp.ndarray, global_idx: jnp.ndarray,
                 shard_offset: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """Materialize rows of the (row-sharded) global array at ``global_idx``
    on every device: masked local scatter + psum.

    ``global_idx`` MUST be replicated (identical on every device) — each
    device contributes the rows it owns and the psum assembles the rest.
    """
    n_local = x_local.shape[0]
    local_pos = global_idx - shard_offset
    valid = (local_pos >= 0) & (local_pos < n_local)
    safe = jnp.clip(local_pos, 0, n_local - 1)
    rows = x_local[safe] * valid[:, None].astype(x_local.dtype)
    return jax.lax.psum(rows, axes)


def make_distributed_corr_sh(mesh: Mesh, *, n: int, d: int, budget: int,
                             metric: str = "l2", backend: str = "reference"):
    """Build the jitted distributed corrSH for a fixed (n, d, budget) — the
    lowerable artifact the dry-run compiles without allocating data."""

    def fn(x_global: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        return _distributed_corr_sh_impl(x_global, key, mesh, budget=budget,
                                         metric=metric, backend=backend)

    return jax.jit(fn)


def distributed_corr_sh(
    x_global: jnp.ndarray,
    key: jax.Array,
    mesh: Mesh,
    *,
    budget: int,
    metric: str = "l2",
    backend: str = "reference",
) -> jnp.ndarray:
    """Medoid of ``x_global: (n, d)`` on ``mesh`` (rows sharded over all axes).

    Returns the global medoid index (replicated scalar). n must be divisible by
    the total device count for the row sharding (pad upstream if needed).
    ``backend`` picks the per-device distance implementation (the Pallas
    backends run the same kernels inside each shard's round).
    """
    return make_distributed_corr_sh(
        mesh, n=int(x_global.shape[0]), d=int(x_global.shape[1]),
        budget=budget, metric=metric, backend=backend)(x_global, key)


def _distributed_corr_sh_impl(
    x_global: jnp.ndarray,
    key: jax.Array,
    mesh: Mesh,
    *,
    budget: int,
    metric: str = "l2",
    backend: str = "reference",
) -> jnp.ndarray:
    axes = tuple(mesh.axis_names)
    num_devices = math.prod(mesh.devices.shape)
    n, d = int(x_global.shape[0]), int(x_global.shape[1])
    if n % num_devices:
        raise ValueError(f"n={n} must be divisible by device count {num_devices}")
    n_local = n // num_devices
    theta_fn = get_backend(backend).centrality_sums(metric)
    rounds = round_schedule(n, budget)

    def shard_fn(x_local: jnp.ndarray, key: jnp.ndarray) -> jnp.ndarray:
        # linear shard id over all mesh axes -> row offset of this shard
        shard_id = jax.lax.axis_index(axes)
        offset = shard_id * n_local

        idx = jnp.arange(n, dtype=jnp.int32)   # surviving arms (replicated)
        theta_hat = None
        for r, rd in enumerate(rounds):
            rkey = jax.random.fold_in(key, r)  # replicated -> shared refs
            if rd.num_refs >= n:
                refs = jnp.arange(n, dtype=jnp.int32)
            else:
                refs = jax.random.permutation(rkey, n)[: rd.num_refs].astype(jnp.int32)
            ref_rows = _gather_rows(x_local, refs, offset, axes)  # (t_r, d) everywhere

            # gather the full (replicated) survivor rows once, then shard the
            # *compute* over devices by slicing candidates locally. NOTE:
            # _gather_rows requires replicated indices, so we gather all of
            # idx (replicated) rather than per-device slices of it.
            s = idx.shape[0]
            per_dev = -(-s // num_devices)
            pad = per_dev * num_devices - s
            idx_p = jnp.pad(idx, (0, pad), constant_values=-1)
            cand_all = _gather_rows(x_local, jnp.where(idx_p >= 0, idx_p, 0),
                                    offset, axes)                  # (s+pad, d)
            my = jax.lax.dynamic_slice_in_dim(idx_p, shard_id * per_dev, per_dev)
            my_valid = my >= 0
            cand_rows = jax.lax.dynamic_slice_in_dim(
                cand_all, shard_id * per_dev, per_dev)             # (per_dev, d)
            local_theta = theta_fn(cand_rows, ref_rows) / ref_rows.shape[0]
            local_theta = jnp.where(my_valid, local_theta, jnp.inf)
            theta_hat = jax.lax.all_gather(local_theta, axes, tiled=True)[:s]

            if rd.exact or s <= 2:
                return idx[jnp.argmin(theta_hat)]
            keep = math.ceil(s / 2)
            # replicated halving: same stable-tie selection as the unified
            # engine (repro.engine.default_select), so distributed survivors
            # match the single-host engine's round for round
            idx = idx[default_select(theta_hat, keep)]
        return idx[jnp.argmin(theta_hat)]

    specs = P(axes)  # rows sharded over all axes jointly
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(specs, P()), out_specs=P())
    return fn(x_global, key)


def make_row_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding that shards axis 0 of a (n, d) dataset over all mesh axes."""
    return NamedSharding(mesh, P(tuple(mesh.axis_names)))
