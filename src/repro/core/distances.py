"""Distance functions for the medoid engine.

All functions compute *blocked* pairwise distances ``D[c, r] = d(X[c], Y[r])``
for ``X: (C, d)``, ``Y: (R, d)`` in pure jnp. These are the reference
implementations; the Pallas kernels in ``repro.kernels`` implement the same
contract with explicit VMEM tiling (and are validated against these).

Supported metrics (paper uses l1, l2, cosine; squared-l2 included because the
paper's Remark 2 covers non-metric divergences):

- ``l1``      : sum |x - y|
- ``l2``      : sqrt(sum (x - y)^2)
- ``sql2``    : sum (x - y)^2            (Bregman; not a metric)
- ``cosine``  : 1 - <x, y> / (|x||y|)
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

METRICS = ("l1", "l2", "sql2", "cosine")


def _gram(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    # (C, d) @ (d, R) in f32 accumulation — MXU path on TPU.
    return jax.lax.dot_general(
        x, y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def pairwise_l1(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    # (C, 1, d) - (1, R, d) -> (C, R, d); reduce over d. VPU-bound.
    return jnp.sum(jnp.abs(x[:, None, :].astype(jnp.float32)
                           - y[None, :, :].astype(jnp.float32)), axis=-1)


def pairwise_sql2(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    x2 = jnp.sum(xf * xf, axis=-1)  # (C,)
    y2 = jnp.sum(yf * yf, axis=-1)  # (R,)
    g = _gram(xf, yf)               # (C, R)
    # Clamp: the Gram trick can go slightly negative from rounding.
    return jnp.maximum(x2[:, None] + y2[None, :] - 2.0 * g, 0.0)


def pairwise_l2(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(pairwise_sql2(x, y))


def pairwise_cosine(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    xn = jnp.sqrt(jnp.sum(xf * xf, axis=-1))
    yn = jnp.sqrt(jnp.sum(yf * yf, axis=-1))
    g = _gram(xf, yf)
    denom = jnp.maximum(xn[:, None] * yn[None, :], 1e-12)
    return 1.0 - g / denom


_PAIRWISE: dict[str, Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]] = {
    "l1": pairwise_l1,
    "l2": pairwise_l2,
    "sql2": pairwise_sql2,
    "cosine": pairwise_cosine,
}


def pairwise(metric: str) -> Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """Return the blocked pairwise-distance function for ``metric``."""
    try:
        return _PAIRWISE[metric]
    except KeyError:
        raise ValueError(f"unknown metric {metric!r}; one of {METRICS}") from None


def masked_rowsum(block: jnp.ndarray,
                  ref_mask: jnp.ndarray | None) -> jnp.ndarray:
    """Row sums of a (C, R) distance block over the valid reference columns
    (``ref_mask`` broadcastable to (R,), nonzero = valid; None = all valid).
    The single definition of out-of-kernel mask semantics — the pairwise
    backends and the ragged engine's legacy-backend fallback all route here."""
    if ref_mask is not None:
        block = block * ref_mask.reshape(-1).astype(block.dtype)[None, :]
    return jnp.sum(block, axis=1)


@functools.partial(jax.jit, static_argnames=("metric",))
def full_distance_matrix(x: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """All-pairs (n, n) distance matrix — used by exact computation & oracles."""
    return pairwise(metric)(x, x)


def centrality_sums(x: jnp.ndarray, refs: jnp.ndarray, metric: str,
                    ref_block: int = 32, d_chunk: int = 256,
                    ref_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """sum_j d(x_i, refs_j) without materializing the (C, R) matrix — the
    memory-bounded form the distributed engine scores rounds with.

    For ℓ1 (no matmul form) the broadcast intermediate is bounded to
    (C, ref_block, d_chunk); Gram-trick metrics just take the row-sum of the
    (cheap) pairwise matrix. ``ref_mask`` (shape (R,), nonzero = valid)
    restricts the sum to valid references — the ragged engine's padded arms
    contribute nothing.
    """
    if metric != "l1":
        return masked_rowsum(pairwise(metric)(x, refs), ref_mask)
    C, d = x.shape
    R = refs.shape[0]
    rb = min(ref_block, R)
    pad = (-R) % rb
    refs_p = jnp.pad(refs, ((0, pad), (0, 0)))
    nb = refs_p.shape[0] // rb
    mask = (jnp.arange(nb * rb) < R).astype(jnp.float32)
    if ref_mask is not None:
        mask = mask * jnp.pad(ref_mask.reshape(-1).astype(jnp.float32),
                              (0, pad))
    mask = mask.reshape(nb, rb)
    xf = x.astype(jnp.float32)

    def body(acc, inp):
        blk, m = inp                                 # (rb, d), (rb,)
        blk = blk.astype(jnp.float32)
        tot = jnp.zeros((C,), jnp.float32)
        for c0 in range(0, d, d_chunk):              # static unroll
            a = jnp.abs(xf[:, None, c0:c0 + d_chunk]
                        - blk[None, :, c0:c0 + d_chunk])
            tot = tot + jnp.einsum("crk,r->c", a, m)
        return acc + tot, 0

    acc, _ = jax.lax.scan(body, jnp.zeros((C,), jnp.float32),
                          (refs_p.reshape(nb, rb, d), mask))
    return acc
