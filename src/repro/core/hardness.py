"""Theorem 2.1 hardness quantities: Delta_i, rho_i, sigma, H2, H~2.

These are the data-dependent constants the paper uses to predict corrSH's
advantage over independent-sampling bandits:

  Delta_i = theta_i - theta_1                       (arm gap; arms sorted)
  sigma   = sqrt(max_i Var_J d(x_i, x_J))           (independent-sampling scale)
  rho_i   = std_J[d(x_1,x_J) - d(x_i,x_J)] / sigma  (correlation gain, <= ~2)

  H2  = max_{i>=2} i / Delta_i^2                    (independent difficulty [7])
  H~2 = max_{i>=2} i * rho_(i)^2 / Delta_(i)^2      (correlated difficulty,
                                                     arms sorted by Delta/rho)

The paper's headline theory number is the ratio H2 / H~2 (6.6 on RNA-Seq 20k,
4.8 on MNIST).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.distances import pairwise


class HardnessStats(NamedTuple):
    theta: jnp.ndarray     # (n,) exact centralities, sorted ascending
    order: jnp.ndarray     # (n,) original indices in sorted order
    delta: jnp.ndarray     # (n,) gaps; delta[0] = 0
    rho: jnp.ndarray       # (n,) correlation factors; rho[0] = 0
    sigma: jnp.ndarray     # scalar
    h2: jnp.ndarray        # scalar
    h2_tilde: jnp.ndarray  # scalar


@functools.partial(jax.jit, static_argnames=("metric",))
def hardness_stats(data: jnp.ndarray, metric: str = "l2") -> HardnessStats:
    """Exact O(n^2) computation of all Theorem 2.1 quantities (benchmark-scale n)."""
    n = data.shape[0]
    dmat = pairwise(metric)(data, data)              # (n, n), D[i, j] = d(x_i, x_j)
    theta = jnp.mean(dmat, axis=1)
    order = jnp.argsort(theta)
    theta_s = theta[order]
    delta = theta_s - theta_s[0]

    # sigma^2 = max_i Var_J d(x_i, x_J) — the sub-Gaussian scale for
    # independent sampling (Hoeffding proxy used throughout the paper).
    var_i = jnp.var(dmat, axis=1)
    sigma = jnp.sqrt(jnp.max(var_i))

    # rho_i * sigma = std_J[ d(x_1, x_J) - d(x_i, x_J) ]  with x_1 the medoid.
    best = order[0]
    diff = dmat[best][None, :] - dmat[order]         # (n, n) rows follow sorted arms
    rho = jnp.std(diff, axis=1) / jnp.maximum(sigma, 1e-12)

    i_idx = jnp.arange(n, dtype=jnp.float32) + 1.0   # 1-based arm index
    safe_delta = jnp.maximum(delta, 1e-12)
    # H2: arms already sorted by Delta (ascending); skip i = 1 (the medoid)
    h2_terms = jnp.where(i_idx >= 2, i_idx / safe_delta**2, -jnp.inf)
    h2 = jnp.max(h2_terms)

    # H~2: re-sort arms by Delta/rho ascending (medoid stays first)
    ratio = jnp.where(i_idx >= 2, safe_delta / jnp.maximum(rho, 1e-12), -jnp.inf)
    perm = jnp.argsort(jnp.where(i_idx >= 2, ratio, -jnp.inf))
    delta_p = safe_delta[perm]
    rho_p = rho[perm]
    ht_terms = jnp.where(i_idx >= 2, i_idx * rho_p**2 / delta_p**2, -jnp.inf)
    h2_tilde = jnp.max(ht_terms)

    return HardnessStats(theta=theta_s, order=order, delta=delta, rho=rho,
                         sigma=sigma, h2=h2, h2_tilde=h2_tilde)


def predicted_error_bound(n: int, budget: int, stats: HardnessStats) -> jnp.ndarray:
    """Theorem 2.1 coarse upper bound on failure probability."""
    import math
    log2n = max(1.0, math.log2(n))
    expo = budget / (16.0 * stats.h2_tilde * stats.sigma**2 * log2n)
    return jnp.minimum(3.0 * log2n * jnp.exp(-expo), 1.0)
