"""Exact O(n^2) medoid computation — ground truth for every benchmark."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.distances import pairwise


@functools.partial(jax.jit, static_argnames=("metric", "block"))
def exact_medoid(data: jnp.ndarray, metric: str = "l2", block: int = 256) -> jnp.ndarray:
    """Return argmin_i sum_j d(x_i, x_j), computed in row blocks to bound memory."""
    n = data.shape[0]
    dist = pairwise(metric)
    pad = (-n) % block
    padded = jnp.pad(data, ((0, pad), (0, 0)))
    nb = padded.shape[0] // block

    def body(carry, i):
        rows = jax.lax.dynamic_slice_in_dim(padded, i * block, block, axis=0)
        sums = jnp.sum(dist(rows, data), axis=1)  # (block,)
        return carry, sums

    _, sums = jax.lax.scan(body, 0, jnp.arange(nb))
    theta = sums.reshape(-1)[:n]
    return jnp.argmin(theta).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("metric", "block"))
def exact_theta(data: jnp.ndarray, metric: str = "l2", block: int = 256) -> jnp.ndarray:
    """All centralities theta_i = (1/n) sum_j d(x_i, x_j)."""
    n = data.shape[0]
    dist = pairwise(metric)
    pad = (-n) % block
    padded = jnp.pad(data, ((0, pad), (0, 0)))
    nb = padded.shape[0] // block

    def body(carry, i):
        rows = jax.lax.dynamic_slice_in_dim(padded, i * block, block, axis=0)
        return carry, jnp.sum(dist(rows, data), axis=1)

    _, sums = jax.lax.scan(body, 0, jnp.arange(nb))
    return sums.reshape(-1)[:n] / n
