"""Paper core: Correlated Sequential Halving medoid identification."""
from repro.core.backend import (
    DistanceBackend,
    get_backend,
    list_backends,
    register_backend,
)
from repro.core.bucketing import (
    DEFAULT_MIN_BUCKET,
    bucket_n,
    num_buckets_for_range,
    pack_queries,
    plan_buckets,
)
from repro.core.corr_sh import (
    CorrSHResult,
    Round,
    corr_sh_medoid,
    corr_sh_medoid_batch,
    corr_sh_medoid_ragged,
    correlated_sequential_halving,
    ragged_compile_count,
    ragged_medoids,
    round_schedule,
    schedule_pulls,
)
from repro.core.distances import METRICS, full_distance_matrix, pairwise
from repro.core.exact import exact_medoid, exact_theta
from repro.core.hardness import HardnessStats, hardness_stats, predicted_error_bound
from repro.core.meddit import MedditResult, meddit_medoid
from repro.core.rand import rand_medoid

__all__ = [
    "CorrSHResult", "DEFAULT_MIN_BUCKET", "DistanceBackend", "Round",
    "bucket_n",
    "corr_sh_medoid", "corr_sh_medoid_batch", "corr_sh_medoid_ragged",
    "correlated_sequential_halving", "get_backend", "list_backends",
    "num_buckets_for_range", "pack_queries", "plan_buckets",
    "ragged_compile_count", "ragged_medoids", "register_backend",
    "round_schedule", "schedule_pulls",
    "METRICS", "full_distance_matrix", "pairwise", "exact_medoid",
    "exact_theta", "HardnessStats", "hardness_stats",
    "predicted_error_bound", "MedditResult", "meddit_medoid", "rand_medoid",
]
