"""Correlated Sequential Halving (Algorithm 1 of the paper).

The crucial systems observation: given ``(n, budget)``, the per-round sizes

    s_r  = |S_r|   (number of surviving arms)
    t_r  = clip(floor(budget / (s_r * ceil(log2 n))), 1, n)

are *deterministic Python integers* — so every round's distance block
``(s_r, t_r)`` has a static shape and the entire algorithm traces into a single
XLA program (the Python loop over rounds unrolls). No dynamic shapes, no host
round-trips, no data-dependent control flow except the final ``t_r == n``
exact-output branch, which is also static.

Faithful to the paper:
  * shared reference set per round (the correlation trick),
  * sampling without replacement (permutation prefix),
  * survivors = ceil(|S_r| / 2) arms with smallest estimates,
  * if t_r == n the round's estimates are exact -> output argmin immediately.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.backend import DistanceBackend, get_backend

PairwiseFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
BackendLike = Union[str, DistanceBackend, None]


@dataclass(frozen=True)
class Round:
    """Static per-round schedule entry."""
    survivors: int   # s_r going *into* the round
    num_refs: int    # t_r
    exact: bool      # t_r == n -> estimates are exact, output now

    @property
    def pulls(self) -> int:
        return self.survivors * self.num_refs


def round_schedule(n: int, budget: int) -> list[Round]:
    """The paper's deterministic round schedule for (n, budget)."""
    if n < 1:
        raise ValueError("need at least one point")
    if n == 1:
        return []
    log2n = max(1, math.ceil(math.log2(n)))
    rounds: list[Round] = []
    s = n
    for _ in range(log2n):
        t = min(max(budget // (s * log2n), 1), n)
        exact = t >= n
        rounds.append(Round(survivors=s, num_refs=t, exact=exact))
        if exact or s <= 1:
            break
        s = math.ceil(s / 2)
        if s == 1:
            break
    return rounds


def schedule_pulls(n: int, budget: int) -> int:
    """Total distance computations the schedule will actually perform."""
    return sum(r.pulls for r in round_schedule(n, budget))


@dataclass
class CorrSHResult:
    medoid: jnp.ndarray                 # scalar int32 index
    pulls: int                          # total distance computations (static)
    rounds: list[Round] = field(default_factory=list)
    theta_hat: Optional[jnp.ndarray] = None  # final-round estimates


def _sample_refs(key: jax.Array, n: int, t: int) -> jnp.ndarray:
    """t reference indices, uniform without replacement (permutation prefix)."""
    if t >= n:
        return jnp.arange(n, dtype=jnp.int32)
    return jax.random.permutation(key, n)[:t].astype(jnp.int32)


def _resolve_theta_fn(metric: str, pairwise_fn: Optional[PairwiseFn],
                      backend: BackendLike) -> Callable:
    """Per-round estimator ``theta_fn(cand, refs) -> (C,)`` *sums* of
    distances (divide by t_r for the mean)."""
    if pairwise_fn is not None:
        return lambda x, y: jnp.sum(pairwise_fn(x, y), axis=1)
    return get_backend(backend).centrality_sums(metric)


def _run_rounds(data: jnp.ndarray, key: jax.Array, rounds: list[Round],
                n: int, theta_fn: Callable):
    """The round loop as a pure array program: static shapes only, no Python
    state in the return value — safe under ``jax.vmap`` (the batched engine
    maps this exact function over a leading batch axis).

    Returns ``(medoid, theta_hat, r_stop)`` where ``r_stop`` is the (static)
    index of the round that produced the output.
    """
    idx = jnp.arange(n, dtype=jnp.int32)  # surviving arm indices, shrinks per round
    theta_hat = None
    for r, rd in enumerate(rounds):
        key, sub = jax.random.split(key)
        refs = _sample_refs(sub, n, rd.num_refs)
        cand_rows = data[idx]                  # (s_r, d)  static gather
        ref_rows = data[refs]                  # (t_r, d)
        theta_hat = theta_fn(cand_rows, ref_rows) / ref_rows.shape[0]  # (s_r,)
        if rd.exact or idx.shape[0] <= 2:
            # exact estimates (t_r == n) or nothing left to halve: output argmin
            return idx[jnp.argmin(theta_hat)], theta_hat, r
        keep = math.ceil(idx.shape[0] / 2)
        # smallest-theta half survives; top_k on negated values, static k
        _, order = jax.lax.top_k(-theta_hat, keep)
        idx = idx[order]
    return idx[jnp.argmin(theta_hat)], theta_hat, len(rounds) - 1


def correlated_sequential_halving(
    data: jnp.ndarray,
    budget: int,
    key: jax.Array,
    metric: str = "l2",
    pairwise_fn: Optional[PairwiseFn] = None,
    backend: BackendLike = "reference",
) -> CorrSHResult:
    """Run Algorithm 1. ``data: (n, d)``; returns the medoid index.

    ``backend`` selects the distance implementation from the registry in
    :mod:`repro.core.backend` (``"reference"``, ``"pallas_pairwise"``,
    ``"pallas_fused"``). ``pairwise_fn`` still overrides the distance block
    directly (legacy hook; takes precedence over ``backend``).
    """
    n = int(data.shape[0])
    rounds = round_schedule(n, budget)
    if not rounds:  # n == 1
        return CorrSHResult(medoid=jnp.zeros((), jnp.int32), pulls=0)
    theta_fn = _resolve_theta_fn(metric, pairwise_fn, backend)
    medoid, theta_hat, r_stop = _run_rounds(data, key, rounds, n, theta_fn)
    return CorrSHResult(
        medoid=medoid,
        pulls=sum(x.pulls for x in rounds[: r_stop + 1]),
        rounds=rounds[: r_stop + 1],
        theta_hat=theta_hat,
    )


@functools.partial(jax.jit, static_argnames=("budget", "metric", "backend"))
def corr_sh_medoid(data: jnp.ndarray, key: jax.Array, *, budget: int,
                   metric: str = "l2",
                   backend: str = "reference") -> jnp.ndarray:
    """Jitted entry point returning just the medoid index."""
    return correlated_sequential_halving(data, budget, key, metric,
                                         backend=backend).medoid


@functools.partial(jax.jit, static_argnames=("budget", "metric", "backend"))
def corr_sh_medoid_batch(data: jnp.ndarray, key: jax.Array, *, budget: int,
                         metric: str = "l2",
                         backend: str = "reference") -> jnp.ndarray:
    """Batched multi-query medoid: ``data (B, n, d) -> (B,)`` indices.

    All queries share one static round schedule (shapes depend only on
    ``(n, budget)``), so the whole batch is a single ``vmap`` of the round
    loop — one XLA program, B independent reference draws (the key is split
    per query; estimates stay independent across the batch). This is the
    k-medoids / multi-tenant serving workload: B candidate sets answered in
    one device dispatch.
    """
    if data.ndim != 3:
        raise ValueError(f"expected (B, n, d) batch, got shape {data.shape}")
    b, n, _ = data.shape
    rounds = round_schedule(n, budget)
    keys = jax.random.split(key, b)
    if not rounds:  # n == 1
        return jnp.zeros((b,), jnp.int32)
    theta_fn = _resolve_theta_fn(metric, None, backend)

    def one(x: jnp.ndarray, k: jax.Array) -> jnp.ndarray:
        return _run_rounds(x, k, rounds, n, theta_fn)[0]

    return jax.vmap(one)(data, keys)
