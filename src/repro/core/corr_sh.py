"""Correlated Sequential Halving (Algorithm 1 of the paper).

The crucial systems observation: given ``(n, budget)``, the per-round sizes

    s_r  = |S_r|   (number of surviving arms)
    t_r  = clip(floor(budget / (s_r * ceil(log2 n))), 1, n)

are *deterministic Python integers* — so every round's distance block
``(s_r, t_r)`` has a static shape and the entire algorithm traces into a single
XLA program (the Python loop over rounds unrolls). No dynamic shapes, no host
round-trips, no data-dependent control flow except the final ``t_r == n``
exact-output branch, which is also static.

Faithful to the paper:
  * shared reference set per round (the correlation trick),
  * sampling without replacement (permutation prefix),
  * survivors = ceil(|S_r| / 2) arms with smallest estimates,
  * if t_r == n the round's estimates are exact -> output argmin immediately.
"""
from __future__ import annotations

import functools
import inspect
import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances
from repro.core.backend import DistanceBackend, get_backend
from repro.core.bucketing import DEFAULT_MIN_BUCKET, bucket_n

PairwiseFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
BackendLike = Union[str, DistanceBackend, None]


@dataclass(frozen=True)
class Round:
    """Static per-round schedule entry."""
    survivors: int   # s_r going *into* the round
    num_refs: int    # t_r
    exact: bool      # t_r == n -> estimates are exact, output now

    @property
    def pulls(self) -> int:
        return self.survivors * self.num_refs


def round_schedule(n: int, budget: int) -> list[Round]:
    """The paper's deterministic round schedule for (n, budget)."""
    if n < 1:
        raise ValueError("need at least one point")
    if n == 1:
        return []
    log2n = max(1, math.ceil(math.log2(n)))
    rounds: list[Round] = []
    s = n
    for _ in range(log2n):
        t = min(max(budget // (s * log2n), 1), n)
        exact = t >= n
        rounds.append(Round(survivors=s, num_refs=t, exact=exact))
        if exact or s <= 1:
            break
        s = math.ceil(s / 2)
        if s == 1:
            break
    return rounds


def schedule_pulls(n: int, budget: int) -> int:
    """Total distance computations the schedule will actually perform."""
    return sum(r.pulls for r in round_schedule(n, budget))


@dataclass
class CorrSHResult:
    medoid: jnp.ndarray                 # scalar int32 index
    pulls: int                          # total distance computations (static)
    rounds: list[Round] = field(default_factory=list)
    theta_hat: Optional[jnp.ndarray] = None  # final-round estimates


def _sample_refs(key: jax.Array, n: int, t: int) -> jnp.ndarray:
    """t reference indices, uniform without replacement (permutation prefix)."""
    if t >= n:
        return jnp.arange(n, dtype=jnp.int32)
    return jax.random.permutation(key, n)[:t].astype(jnp.int32)


def _resolve_theta_fn(metric: str, pairwise_fn: Optional[PairwiseFn],
                      backend: BackendLike) -> Callable:
    """Per-round estimator ``theta_fn(cand, refs) -> (C,)`` *sums* of
    distances (divide by t_r for the mean)."""
    if pairwise_fn is not None:
        return lambda x, y: jnp.sum(pairwise_fn(x, y), axis=1)
    return get_backend(backend).centrality_sums(metric)


def _default_select(theta: jnp.ndarray, keep: int) -> jnp.ndarray:
    """Survivor selection: indices of the ``keep`` smallest estimates,
    ascending, ties stable toward the smaller index (top_k on negated
    values, static k)."""
    return jax.lax.top_k(-theta, keep)[1]


def _resolve_select_fn(backend: BackendLike) -> Callable:
    """The halving step's top-k: a backend with a fused survivor-selection
    epilogue (``survivor_topk``, e.g. ``pallas_fused_topk``) keeps it
    on-chip; everyone else gets the default XLA top_k. Both have identical
    stable-tie semantics, so the choice never changes survivors."""
    fn = get_backend(backend).survivor_topk
    return fn if fn is not None else _default_select


def _run_rounds(data: jnp.ndarray, key: jax.Array, rounds: list[Round],
                n: int, theta_fn: Callable,
                select_fn: Callable = _default_select):
    """The round loop as a pure array program: static shapes only, no Python
    state in the return value — safe under ``jax.vmap`` (the batched engine
    maps this exact function over a leading batch axis).

    Returns ``(medoid, theta_hat, r_stop)`` where ``r_stop`` is the (static)
    index of the round that produced the output.
    """
    idx = jnp.arange(n, dtype=jnp.int32)  # surviving arm indices, shrinks per round
    theta_hat = None
    for r, rd in enumerate(rounds):
        key, sub = jax.random.split(key)
        refs = _sample_refs(sub, n, rd.num_refs)
        cand_rows = data[idx]                  # (s_r, d)  static gather
        ref_rows = data[refs]                  # (t_r, d)
        theta_hat = theta_fn(cand_rows, ref_rows) / ref_rows.shape[0]  # (s_r,)
        if rd.exact or idx.shape[0] <= 2:
            # exact estimates (t_r == n) or nothing left to halve: output argmin
            return idx[jnp.argmin(theta_hat)], theta_hat, r
        keep = math.ceil(idx.shape[0] / 2)
        idx = idx[select_fn(theta_hat, keep)]   # smallest-theta half survives
    return idx[jnp.argmin(theta_hat)], theta_hat, len(rounds) - 1


def correlated_sequential_halving(
    data: jnp.ndarray,
    budget: int,
    key: jax.Array,
    metric: str = "l2",
    pairwise_fn: Optional[PairwiseFn] = None,
    backend: BackendLike = "reference",
) -> CorrSHResult:
    """Run Algorithm 1. ``data: (n, d)``; returns the medoid index.

    ``backend`` selects the distance implementation from the registry in
    :mod:`repro.core.backend` (``"reference"``, ``"pallas_pairwise"``,
    ``"pallas_fused"``). ``pairwise_fn`` still overrides the distance block
    directly (legacy hook; takes precedence over ``backend``).
    """
    n = int(data.shape[0])
    rounds = round_schedule(n, budget)
    if not rounds:  # n == 1
        return CorrSHResult(medoid=jnp.zeros((), jnp.int32), pulls=0)
    theta_fn = _resolve_theta_fn(metric, pairwise_fn, backend)
    select_fn = _resolve_select_fn(backend)
    medoid, theta_hat, r_stop = _run_rounds(data, key, rounds, n, theta_fn,
                                            select_fn)
    return CorrSHResult(
        medoid=medoid,
        pulls=sum(x.pulls for x in rounds[: r_stop + 1]),
        rounds=rounds[: r_stop + 1],
        theta_hat=theta_hat,
    )


@functools.partial(jax.jit, static_argnames=("budget", "metric", "backend"))
def corr_sh_medoid(data: jnp.ndarray, key: jax.Array, *, budget: int,
                   metric: str = "l2",
                   backend: str = "reference") -> jnp.ndarray:
    """Jitted entry point returning just the medoid index."""
    return correlated_sequential_halving(data, budget, key, metric,
                                         backend=backend).medoid


@functools.partial(jax.jit, static_argnames=("budget", "metric", "backend"))
def corr_sh_medoid_batch(data: jnp.ndarray, key: jax.Array, *, budget: int,
                         metric: str = "l2",
                         backend: str = "reference") -> jnp.ndarray:
    """Batched multi-query medoid: ``data (B, n, d) -> (B,)`` indices.

    All queries share one static round schedule (shapes depend only on
    ``(n, budget)``), so the whole batch is a single ``vmap`` of the round
    loop — one XLA program, B independent reference draws (the key is split
    per query; estimates stay independent across the batch). This is the
    k-medoids / multi-tenant serving workload: B candidate sets answered in
    one device dispatch.
    """
    if data.ndim != 3:
        raise ValueError(f"expected (B, n, d) batch, got shape {data.shape}")
    b, n, _ = data.shape
    rounds = round_schedule(n, budget)
    keys = jax.random.split(key, b)
    if not rounds:  # n == 1
        return jnp.zeros((b,), jnp.int32)
    theta_fn = _resolve_theta_fn(metric, None, backend)
    select_fn = _resolve_select_fn(backend)

    def one(x: jnp.ndarray, k: jax.Array) -> jnp.ndarray:
        return _run_rounds(x, k, rounds, n, theta_fn, select_fn)[0]

    return jax.vmap(one)(data, keys)


# ---------------------------------------------------------------------------
# ragged multi-query engine: per-query n via padding + validity masking
# ---------------------------------------------------------------------------

def _sample_refs_masked(key: jax.Array, n: int, t: int,
                        valid: jnp.ndarray) -> jnp.ndarray:
    """t reference indices favoring valid points: a uniform permutation of
    [0, n) stably partitioned so valid indices come first (still in random
    order — sampling without replacement among the valid points), invalid
    ones trail. When every point is valid this is exactly ``_sample_refs``
    (the stable partition of an all-zero rank is the identity), which is what
    makes the ragged engine bit-identical to the dense one on full buckets.
    """
    if t >= n:
        return jnp.arange(n, dtype=jnp.int32)
    perm = jax.random.permutation(key, n).astype(jnp.int32)
    order = jnp.argsort(jnp.where(valid[perm], 0, 1))  # jnp sort is stable
    return perm[order][:t]


def _resolve_masked_theta_fn(metric: str, backend: BackendLike) -> Callable:
    """Mask-aware per-round estimator ``fn(cand, refs, ref_mask) -> (C,)``
    sums over the *valid* references only. Built-in backends take ``ref_mask``
    natively (the fused kernels apply it in VMEM); for a registered backend
    that predates the keyword, fall back to masking its pairwise block."""
    be = get_backend(backend)
    fn = be.centrality_sums(metric)
    try:
        params = inspect.signature(fn).parameters
        mask_native = "ref_mask" in params or any(
            p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())
    except (TypeError, ValueError):   # builtins / odd callables: probe-free
        mask_native = False
    if mask_native:
        return lambda x, y, m: fn(x, y, ref_mask=m)
    pw = be.pairwise(metric)
    return lambda x, y, m: distances.masked_rowsum(pw(x, y), m)


def _run_rounds_masked(data: jnp.ndarray, valid: jnp.ndarray, key: jax.Array,
                       rounds: list[Round], n: int, theta_fn: Callable,
                       select_fn: Callable = _default_select):
    """The round loop of ``_run_rounds`` generalized to a validity mask.

    ``valid: (n,) bool`` marks real points; padded arms get +inf estimates
    (never survive a halving ahead of any real arm, never win the argmin) and
    contribute nothing as references (masked inside the distance path;
    estimates divide by the drawn *valid* count). On an all-valid query every
    array this computes is identical to ``_run_rounds`` — the parity the
    ragged tests pin down.
    """
    idx = jnp.arange(n, dtype=jnp.int32)   # surviving arm indices
    theta_hat = None
    for r, rd in enumerate(rounds):
        key, sub = jax.random.split(key)
        refs = _sample_refs_masked(sub, n, rd.num_refs, valid)
        ref_mask = valid[refs].astype(jnp.float32)          # (t_r,)
        sums = theta_fn(data[idx], data[refs], ref_mask)    # (s_r,) valid sums
        denom = jnp.maximum(jnp.sum(ref_mask), 1.0)
        theta_hat = jnp.where(valid[idx], sums / denom, jnp.inf)
        if rd.exact or idx.shape[0] <= 2:
            return idx[jnp.argmin(theta_hat)], theta_hat, r
        keep = math.ceil(idx.shape[0] / 2)
        idx = idx[select_fn(theta_hat, keep)]
    return idx[jnp.argmin(theta_hat)], theta_hat, len(rounds) - 1


# Compilation odometer: bumped at *trace* time, i.e. exactly once per XLA
# program the ragged engine compiles. The bucketing invariants ("a sweep over
# mixed-n traffic compiles at most one program per bucket") are asserted
# against this counter by the service tests and bench_ragged.
_RAGGED_TRACES = 0


def ragged_compile_count() -> int:
    """Number of distinct XLA programs traced by the ragged engine so far."""
    return _RAGGED_TRACES


@functools.partial(jax.jit,
                   static_argnames=("budget", "metric", "backend", "n_bucket"))
def _ragged_impl(data: jnp.ndarray, lengths: jnp.ndarray, key: jax.Array, *,
                 budget: int, metric: str, backend: str,
                 n_bucket: int) -> jnp.ndarray:
    global _RAGGED_TRACES
    _RAGGED_TRACES += 1                      # runs once per compilation
    b = data.shape[0]
    rounds = round_schedule(n_bucket, budget)
    if not rounds:                           # n_bucket == 1
        return jnp.zeros((b,), jnp.int32)
    valid = jnp.arange(n_bucket, dtype=jnp.int32)[None, :] < lengths[:, None]
    keys = jax.random.split(key, b)
    theta_fn = _resolve_masked_theta_fn(metric, backend)
    select_fn = _resolve_select_fn(backend)

    def one(x: jnp.ndarray, v: jnp.ndarray, k: jax.Array) -> jnp.ndarray:
        return _run_rounds_masked(x, v, k, rounds, n_bucket, theta_fn,
                                  select_fn)[0]

    return jax.vmap(one)(data, valid, keys)


def corr_sh_medoid_ragged(data: jnp.ndarray, lengths, key: jax.Array, *,
                          budget: int, metric: str = "l2",
                          backend: str = "reference",
                          min_bucket: int = DEFAULT_MIN_BUCKET) -> jnp.ndarray:
    """Ragged multi-query medoid: ``data (B, n_max, d)`` + per-query
    ``lengths (B,)`` -> ``(B,)`` medoid indices (each < its query's length).

    Queries of heterogeneous sizes ride one XLA program: ``n_max`` is rounded
    up to a power-of-two bucket (see :mod:`repro.core.bucketing` — this caps
    compilations across arbitrary traffic), one static round schedule is
    computed from ``(n_bucket, budget)``, and per-query padding is handled by
    in-round validity masking — padded arms take +inf centrality and are
    never counted as references. A query occupying its full bucket
    (``length == n_bucket``) follows the exact same schedule, reference draws
    and arithmetic as ``corr_sh_medoid(data[i], split(key, B)[i], ...)``.

    Raises ``ValueError`` on an all-padding query (``length < 1``) or a
    length exceeding ``n_max`` — rejected at admission, before any dispatch.
    """
    if data.ndim != 3:
        raise ValueError(f"expected (B, n_max, d) batch, got shape {data.shape}")
    lengths = jnp.asarray(lengths, jnp.int32)
    if lengths.shape != (data.shape[0],):
        raise ValueError(f"lengths must be ({data.shape[0]},), "
                         f"got {lengths.shape}")
    try:                      # host-side admission checks (concrete lengths)
        lens = np.asarray(lengths)
    except jax.errors.TracerArrayConversionError:
        lens = None           # called under an outer trace: caller's problem
    if lens is not None:
        if (lens < 1).any():
            raise ValueError("all-padding query rejected: every query needs "
                             f"length >= 1, got lengths={lens.tolist()}")
        if (lens > data.shape[1]).any():
            raise ValueError(f"length exceeds padded arm count "
                             f"{data.shape[1]}: lengths={lens.tolist()}")
    # Bucket-pad OUTSIDE the jitted impl: the raw n_max must never reach the
    # jit cache key, or every distinct caller padding would compile its own
    # program and the per-bucket compile cap would silently evaporate.
    n_bucket = bucket_n(data.shape[1], min_bucket)
    if data.shape[1] < n_bucket:
        data = jnp.pad(data, ((0, 0), (0, n_bucket - data.shape[1]), (0, 0)))
    return _ragged_impl(data, lengths, key, budget=budget, metric=metric,
                        backend=backend, n_bucket=n_bucket)
