"""Correlated Sequential Halving (Algorithm 1 of the paper) — engine adapters.

As of PR 4 the round loop itself lives in :mod:`repro.engine.halving`
(:func:`~repro.engine.run_halving`, parameterized by an
:class:`~repro.engine.ArmEstimator`); this module keeps the paper-facing
medoid entry points as thin adapters over it:

* :func:`correlated_sequential_halving` — the research-level function
  returning the full :class:`CorrSHResult` (medoid, pulls, rounds, final
  estimates);
* ``_medoid_impl`` / ``_batch_impl`` / :func:`ragged_medoids` — the
  internal entry points the facade (:mod:`repro.api`), the serving layer,
  and the clustering refiners dispatch to. Since PR 6 these are thin
  wrappers over the cached jitted programs of
  :mod:`repro.engine.programs` — keyed by (bucket, schedule config,
  backend), so repeated same-shape calls never retrace, with optional arm
  buffer donation for callers that own their packed buffers;
* :func:`corr_sh_medoid`, :func:`corr_sh_medoid_batch`,
  :func:`corr_sh_medoid_ragged` — the pre-facade public names, kept
  signature-compatible as deprecated shims (one ``DeprecationWarning`` per
  process; use :mod:`repro.api`).

Everything the old in-module loops guaranteed still holds — static shapes
from :func:`~repro.engine.schedule.round_schedule`, shared per-round
reference draws, bit-exact full-bucket parity between the ragged and dense
paths — and is now pinned against verbatim pre-refactor loop snapshots by
``tests/test_engine.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import DistanceBackend
from repro.core.bucketing import DEFAULT_MIN_BUCKET, bucket_n
from repro.deprecation import warn_once
from repro.engine import (HalvingProblem, Round, medoid_centrality,
                          round_schedule, run_halving, schedule_pulls)
from repro.engine import instrument, programs

PairwiseFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
BackendLike = Union[str, DistanceBackend, None]

__all__ = [
    "CorrSHResult", "Round", "corr_sh_medoid", "corr_sh_medoid_batch",
    "corr_sh_medoid_ragged", "correlated_sequential_halving",
    "ragged_compile_count", "ragged_medoids", "round_schedule",
    "schedule_pulls",
]


@dataclass
class CorrSHResult:
    medoid: jnp.ndarray                 # scalar int32 index
    pulls: int                          # total distance computations (static)
    rounds: list[Round] = field(default_factory=list)
    theta_hat: Optional[jnp.ndarray] = None  # final-round estimates


def correlated_sequential_halving(
    data: jnp.ndarray,
    budget: int,
    key: jax.Array,
    metric: str = "l2",
    pairwise_fn: Optional[PairwiseFn] = None,
    backend: BackendLike = "reference",
) -> CorrSHResult:
    """Run Algorithm 1. ``data: (n, d)``; returns the medoid index.

    ``backend`` selects the distance implementation from the registry in
    :mod:`repro.core.backend` (``"reference"``, ``"pallas_pairwise"``,
    ``"pallas_fused"``, ``"pallas_fused_topk"``). ``pairwise_fn`` still
    overrides the distance block directly (legacy hook; takes precedence
    over ``backend``).
    """
    n = int(data.shape[0])
    rounds = round_schedule(n, budget)
    if not rounds:  # n == 1
        return CorrSHResult(medoid=jnp.zeros((), jnp.int32), pulls=0)
    problem = HalvingProblem(
        data, medoid_centrality(backend, metric, pairwise_fn=pairwise_fn))
    out = run_halving(problem, rounds, backend, key=key)
    return CorrSHResult(
        medoid=out.winner,
        pulls=sum(x.pulls for x in rounds[: out.r_stop + 1]),
        rounds=rounds[: out.r_stop + 1],
        theta_hat=out.theta,
    )


def _medoid_impl(data: jnp.ndarray, key: jax.Array, *, budget: int,
                 metric: str = "l2", backend: str = "reference",
                 donate: bool = False, telemetry: bool = False,
                 precision: str = "fp32", error_model: str = "probe"):
    """Single-query medoid (the facade's ``find_medoid`` kernel): dispatch
    the cached jitted program for this (budget, metric, backend) config.
    With ``telemetry`` the program returns ``(index, per-round telemetry)``
    — same single dispatch (see :mod:`repro.obs.telemetry`). Quantized
    programs (``precision != "fp32"``) additionally return the traced
    ``verified`` certificate right after the index."""
    instrument.note_dispatch("medoid")
    fn = programs.medoid_program(budget=budget, metric=metric,
                                 backend=backend, donate=donate,
                                 telemetry=telemetry, precision=precision,
                                 error_model=error_model)
    return fn(data, key)


def _batch_impl(data: jnp.ndarray, key: jax.Array, *, budget: int,
                metric: str = "l2", backend: str = "reference",
                donate: bool = False, telemetry: bool = False,
                precision: str = "fp32", error_model: str = "probe"):
    """Batched multi-query medoid: ``data (B, n, d) -> (B,)`` indices
    (``((B,), telemetry)`` with ``telemetry``).

    All queries share one static round schedule (shapes depend only on
    ``(n, budget)``), so the whole batch is a single ``vmap`` of the round
    loop — one XLA program, B independent reference draws (the key is split
    per query; estimates stay independent across the batch). This is the
    k-medoids / multi-tenant serving workload: B candidate sets answered in
    one device dispatch.
    """
    if data.ndim != 3:
        raise ValueError(f"expected (B, n, d) batch, got shape {data.shape}")
    instrument.note_dispatch("batch")
    fn = programs.batch_program(budget=budget, metric=metric,
                                backend=backend, donate=donate,
                                telemetry=telemetry, precision=precision,
                                error_model=error_model)
    return fn(data, key)


# ---------------------------------------------------------------------------
# ragged multi-query engine: per-query n via padding + validity masking
# ---------------------------------------------------------------------------

def ragged_compile_count() -> int:
    """Number of distinct XLA programs traced by the ragged engine so far
    (the ``"ragged"`` odometer of :mod:`repro.engine.instrument` — bumped at
    *trace* time, exactly once per compiled program). The bucketing
    invariants ("a sweep over mixed-n traffic compiles at most one program
    per bucket") are asserted against this counter by the service tests and
    bench_ragged."""
    return instrument.trace_count("ragged")


def ragged_medoids(data: jnp.ndarray, lengths, key: jax.Array, *,
                   budget: int, metric: str = "l2",
                   backend: str = "reference",
                   min_bucket: int = DEFAULT_MIN_BUCKET,
                   donate: bool = False, telemetry: bool = False,
                   precision: str = "fp32", error_model: str = "probe"):
    """Ragged multi-query medoid: ``data (B, n_max, d)`` + per-query
    ``lengths (B,)`` -> ``(B,)`` medoid indices (each < its query's length);
    ``((B,) indices, telemetry)`` with ``telemetry``.

    Queries of heterogeneous sizes ride one XLA program: ``n_max`` is rounded
    up to a power-of-two bucket (see :mod:`repro.core.bucketing` — this caps
    compilations across arbitrary traffic), one static round schedule is
    computed from ``(n_bucket, budget)``, and per-query padding is handled by
    in-round validity masking — padded arms take +inf centrality and are
    never counted as references. A query occupying its full bucket
    (``length == n_bucket``) follows the exact same schedule, reference draws
    and arithmetic as a single-query ``find_medoid(data[i], split(key, B)[i])``.

    Raises ``ValueError`` on an all-padding query (``length < 1``) or a
    length exceeding ``n_max`` — rejected at admission, before any dispatch.
    ``donate=True`` donates the (bucket-padded) arm buffer to the program —
    only for callers that own the packed buffer and never reuse it (the
    facade and the medoid server set it for buffers they packed themselves).
    """
    if data.ndim != 3:
        raise ValueError(f"expected (B, n_max, d) batch, got shape {data.shape}")
    lengths = jnp.asarray(lengths, jnp.int32)
    if lengths.shape != (data.shape[0],):
        raise ValueError(f"lengths must be ({data.shape[0]},), "
                         f"got {lengths.shape}")
    try:                      # host-side admission checks (concrete lengths)
        lens = np.asarray(lengths)
    except jax.errors.TracerArrayConversionError:
        lens = None           # called under an outer trace: caller's problem
    if lens is not None:
        if (lens < 1).any():
            raise ValueError("all-padding query rejected: every query needs "
                             f"length >= 1, got lengths={lens.tolist()}")
        if (lens > data.shape[1]).any():
            raise ValueError(f"length exceeds padded arm count "
                             f"{data.shape[1]}: lengths={lens.tolist()}")
    # Bucket-pad OUTSIDE the jitted impl: the raw n_max must never reach the
    # jit cache key, or every distinct caller padding would compile its own
    # program and the per-bucket compile cap would silently evaporate.
    n_bucket = bucket_n(data.shape[1], min_bucket)
    if data.shape[1] < n_bucket:
        data = jnp.pad(data, ((0, 0), (0, n_bucket - data.shape[1]), (0, 0)))
    instrument.note_dispatch("ragged")
    fn = programs.ragged_program(n_bucket=n_bucket, budget=budget,
                                 metric=metric, backend=backend,
                                 donate=donate, telemetry=telemetry,
                                 precision=precision,
                                 error_model=error_model)
    return fn(data, lengths, key)


# ---------------------------------------------------------------------------
# deprecated pre-facade entry points (use repro.api)
# ---------------------------------------------------------------------------

def corr_sh_medoid(data: jnp.ndarray, key: jax.Array, *, budget: int,
                   metric: str = "l2",
                   backend: str = "reference") -> jnp.ndarray:
    """Deprecated: use :func:`repro.api.find_medoid`."""
    warn_once("repro.core.corr_sh.corr_sh_medoid", "repro.api.find_medoid")
    return _medoid_impl(data, key, budget=budget, metric=metric,
                        backend=backend)


def corr_sh_medoid_batch(data: jnp.ndarray, key: jax.Array, *, budget: int,
                         metric: str = "l2",
                         backend: str = "reference") -> jnp.ndarray:
    """Deprecated: use :func:`repro.api.find_medoids_batch`."""
    warn_once("repro.core.corr_sh.corr_sh_medoid_batch",
              "repro.api.find_medoids_batch")
    return _batch_impl(data, key, budget=budget, metric=metric,
                       backend=backend)


def corr_sh_medoid_ragged(data: jnp.ndarray, lengths, key: jax.Array, *,
                          budget: int, metric: str = "l2",
                          backend: str = "reference",
                          min_bucket: int = DEFAULT_MIN_BUCKET) -> jnp.ndarray:
    """Deprecated: use :func:`repro.api.find_medoids_ragged`."""
    warn_once("repro.core.corr_sh.corr_sh_medoid_ragged",
              "repro.api.find_medoids_ragged")
    return ragged_medoids(data, lengths, key, budget=budget, metric=metric,
                          backend=backend, min_bucket=min_bucket)
