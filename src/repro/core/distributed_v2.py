"""Communication-optimal distributed Correlated Sequential Halving (v2).

The v1 engine (distributed.py) replicates the surviving candidate rows to
every device each round (psum-gather of (s_r, d)) — Σ_r s_r ≈ 2n rows of
traffic, 27.5 GB/chip collective on the (n=2^20, d=1024) production cell,
0.55 s/run, collective-bound (measured, EXPERIMENTS §Perf).

v2 restructures the round loop around where the data already lives:

  * **Stratified reference sampling**: each round's reference set draws
    exactly t_r / P points from every shard (without replacement within the
    shard). Still uniform over the dataset and unbiased for θ_i; stratification
    only *reduces* the variance of the shared-reference estimator (standard
    stratified-sampling argument), so Theorem 2.1's guarantee is preserved
    with the same ρ_i σ. This is the beyond-paper change that makes reference
    locality *free*.

  * **Early rounds (s_r large): candidates stay in place.** Each device
    scores its own shard rows against the (tiny, globally gathered)
    stratified reference set; survivor state is a boolean mask over local
    rows. Communication: t_r x d ref rows + an (n,) float all-gather of
    estimates. Wasted compute factor n / s_r, bounded by the switch below.

  * **Late rounds (s_r small): candidates replicate, references stay local.**
    Survivor rows are psum-gathered once ((s_r, d), bf16 on the wire) and
    every device scores them against its *local* stratified references —
    zero reference communication — followed by an (s_r,) psum of partial
    sums.

  * **Mode switch** at s_r <= candidates_gather_threshold (default 4 n/P):
    per-round costs are static, so the schedule picks the cheaper mode at
    trace time.

Napkin math for the production cell (P=256, n=2^20, d=1024, T=24n):
  v1 collective  ~ Σ_r 2(s_r + t_r) d * 4B    ~ 27 GB/chip
  v2 collective  ~ Σ_early t_r d * 8B + Σ_late 2 s_r d * 2B + (n,) gathers
                 ~ tens of MB/chip  (~1000x less)
  v2 compute     ~ Σ_early (n/P) t_r d + Σ_late s_r (t_r / P) d  ~ 4 GFLOP/chip
Expected: collective-bound -> compute/memory-bound, >10x step-time.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.backend import get_backend
from repro.core.distributed import shard_map
from repro.engine import default_select, round_schedule


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def survivor_keep_mask(theta_global: jnp.ndarray, keep: int,
                       offset, n_local: int):
    """Local-shard membership mask for the ``keep`` smallest global estimates.

    Selecting survivors with a value threshold (``theta <= kth``) keeps MORE
    than ``keep`` arms when estimates tie at the k-th value (common for
    integer / one-hot data), silently breaking the static round schedule.
    ``lax.top_k`` breaks ties by lower index, so membership in its index set
    keeps *exactly* ``keep`` arms — the same tie-break the compact
    (``surv_idx``) path uses. Returns ``(local_mask, order)``: the boolean
    mask over this shard's ``n_local`` rows and the global top-k indices.
    """
    n = theta_global.shape[0]
    order = default_select(theta_global, keep)
    keep_global = jnp.zeros((n,), bool).at[order].set(True)
    local = jax.lax.dynamic_slice_in_dim(keep_global, offset, n_local)
    return local, order.astype(jnp.int32)


def make_distributed_corr_sh_v2(mesh: Mesh, *, n: int, d: int, budget: int,
                                metric: str = "l2",
                                backend: str = "reference",
                                gather_threshold_factor: int = 4,
                                wire_dtype=jnp.bfloat16):
    axes = tuple(mesh.axis_names)
    num_devices = math.prod(mesh.devices.shape)
    if n % num_devices:
        raise ValueError(f"n={n} must divide device count {num_devices}")
    n_local = n // num_devices
    theta_sums = get_backend(backend).centrality_sums(metric)
    rounds = round_schedule(n, budget)
    threshold = gather_threshold_factor * n_local

    def shard_fn(x_local: jnp.ndarray, key: jnp.ndarray) -> jnp.ndarray:
        shard_id = jax.lax.axis_index(axes)
        offset = shard_id * n_local
        local_ids = offset + jnp.arange(n_local, dtype=jnp.int32)

        alive = jnp.ones((n_local,), bool)       # in-place survivor mask
        surv_idx = None                          # compact survivors (late mode)
        theta_global = jnp.full((n,), jnp.inf, jnp.float32)

        for r, rd in enumerate(rounds):
            s_r = rd.survivors
            # stratified reference split. t_r >= P: ceil(t_r/P) per shard
            # (budget round-up <= P rows). t_r < P: a rotating subset of t_r
            # shards contributes one reference each (rows are assumed
            # shuffled across shards, so shard-subset sampling stays uniform
            # in distribution — see module docstring).
            if rd.num_refs >= num_devices:
                t_local = -(-rd.num_refs // num_devices)
                t_r = t_local * num_devices
                sel = jnp.ones((), jnp.float32)
                slot = shard_id * t_local
            else:
                t_local = 1
                t_r = rd.num_refs
                rot = (shard_id - r * 31) % num_devices
                sel = (rot < t_r).astype(jnp.float32)
                slot = jnp.clip(rot, 0, t_r - 1)

            rkey = jax.random.fold_in(key, r)
            skey = jax.random.fold_in(rkey, shard_id)   # per-shard draw
            perm = jax.random.permutation(skey, n_local)[:t_local]
            local_refs = x_local[perm]                   # (t_local, d) compact

            if s_r > threshold and surv_idx is None:
                # ---- in-place mode: gather refs globally, score local rows
                ref_rows = jnp.zeros((t_r, d), x_local.dtype)
                ref_rows = jax.lax.dynamic_update_slice_in_dim(
                    ref_rows, local_refs * sel.astype(x_local.dtype),
                    slot, axis=0)
                ref_rows = jax.lax.psum(ref_rows, axes)          # (t_r, d)
                theta_loc = theta_sums(x_local, ref_rows) / t_r
                theta_loc = jnp.where(alive, theta_loc, jnp.inf)
                theta_global = jax.lax.all_gather(theta_loc, axes, tiled=True)
                if rd.exact or s_r <= 2:
                    return jnp.argmin(theta_global).astype(jnp.int32)
                keep = math.ceil(s_r / 2)
                # keep exactly the k smallest estimates, ties broken by index
                # (a value threshold over-keeps on ties — see survivor_keep_mask)
                local_keep, order = survivor_keep_mask(theta_global, keep,
                                                       offset, n_local)
                alive = alive & local_keep
                if keep <= threshold:
                    # transition: materialize the compact survivor index list
                    surv_idx = order                             # replicated
            else:
                # ---- replicate mode: gather survivor rows, refs stay local
                if surv_idx is None:   # first round already small
                    surv_idx = jnp.arange(n, dtype=jnp.int32)[:s_r]
                s = surv_idx.shape[0]
                local_pos = surv_idx - offset
                valid = (local_pos >= 0) & (local_pos < n_local)
                safe = jnp.clip(local_pos, 0, n_local - 1)
                contrib = (x_local[safe]
                           * valid[:, None].astype(x_local.dtype))
                cand = jax.lax.psum(contrib.astype(wire_dtype), axes)  # (s, d)
                part = theta_sums(cand.astype(x_local.dtype), local_refs) * sel
                theta = jax.lax.psum(part, axes) / t_r           # (s,)
                if rd.exact or s <= 2:
                    return surv_idx[jnp.argmin(theta)]
                keep = math.ceil(s / 2)
                surv_idx = surv_idx[default_select(theta, keep)]

        if surv_idx is not None:
            return surv_idx[0]
        return jnp.argmin(theta_global).astype(jnp.int32)

    fn = shard_map(shard_fn, mesh=mesh, in_specs=(P(axes), P()), out_specs=P())
    return jax.jit(fn)


def distributed_corr_sh_v2(x_global, key, mesh, *, budget: int,
                           metric: str = "l2", **kw):
    return make_distributed_corr_sh_v2(
        mesh, n=int(x_global.shape[0]), d=int(x_global.shape[1]),
        budget=budget, metric=metric, **kw)(x_global, key)
