"""Pluggable distance backends for the medoid engines.

Every corrSH round boils down to two primitives over a candidate block
``x: (C, d)`` and a reference block ``y: (R, d)``:

* ``pairwise(metric)(x, y) -> (C, R)`` — the full distance block;
* ``centrality_sums(metric)(x, y, ref_mask=None) -> (C,)`` — row sums
  ``sum_j d(x_i, y_j)``, which is all the algorithm actually needs (estimates
  are means). The optional ``ref_mask`` keyword (shape (R,), nonzero = valid)
  restricts the sum to valid references: the ragged multi-query engine pads
  short queries up to a shared bucket size and masks the padded arms out of
  every round *inside* the distance path (the fused Pallas kernels apply the
  mask in VMEM, so invalid references cost no HBM traffic either).

A :class:`DistanceBackend` bundles one implementation of each, and the
single-host (:mod:`repro.core.corr_sh`), batched, and distributed
(:mod:`repro.core.distributed`, :mod:`repro.core.distributed_v2`) engines all
consume the backend instead of hardcoding a distance path. Registered
backends:

``reference``
    Pure-jnp blocked distances (:mod:`repro.core.distances`). The ground
    truth everything else is validated against; ℓ1 centrality is
    memory-bounded via the scan in ``distances.centrality_sums``.

``pallas_pairwise``
    Pallas kernels for the (C, R) block (MXU Gram kernel for l2/sql2/cosine,
    VPU kernel for ℓ1); centrality is a row-sum *outside* the kernel, so the
    block still round-trips through HBM.

``pallas_fused``
    Fused centrality kernels: the ℓ1 VPU kernel and the MXU
    ``dot_centrality`` kernel reduce over references *inside* the kernel —
    no round ever materializes the (s_r, t_r) block in HBM, for any metric.
    This is the memory-roofline-optimal production path.

``pallas_fused_topk``
    ``pallas_fused`` plus the fused top-k survivor-selection epilogue
    (:func:`repro.kernels.ops.kernel_topk_smallest`): the halving step's
    top-k runs as an on-chip rank/select kernel pair instead of XLA's
    generic sort, with bit-identical stable-tie semantics — no step of a
    round leaves the chip.

On non-TPU hosts the Pallas backends transparently run in interpret mode
(see :mod:`repro.kernels.ops`), so every backend is selectable everywhere.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Mapping, Optional, Union

import jax.numpy as jnp

from repro.core import distances
from repro.kernels import ops as kops

PairwiseFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
# (x, y) -> (C,) sums; built-in backends also take ref_mask= (see module doc).
CentralityFn = Callable[..., jnp.ndarray]


@dataclass(frozen=True)
class DistanceBackend:
    """One implementation of the round primitives, keyed by metric name.

    ``centrality_sums(metric)`` should return a function that also accepts an
    optional ``ref_mask=`` keyword; backends that don't are still usable —
    the ragged engine falls back to masking their ``pairwise`` block.
    """
    name: str
    pairwise: Callable[[str], PairwiseFn]
    centrality_sums: Callable[[str], CentralityFn]
    materializes_block: bool   # does centrality ever put (C, R) in HBM?
    description: str = ""
    # Optional fused survivor-selection epilogue: ``fn(theta, keep)`` returns
    # the indices of the ``keep`` smallest estimates with jax.lax.top_k's
    # exact stable-tie semantics. When set, the round loops route the halving
    # step through it instead of the default XLA top_k — the last off-chip
    # step of a round stays on-chip. ``None`` = default selection.
    survivor_topk: Optional[Callable[[jnp.ndarray, int], jnp.ndarray]] = None
    # Optional fused survivor-ordering epilogue: ``fn(theta)`` returns the
    # full stable ascending ordering of the estimates (``argsort`` with
    # jax.lax.top_k's exact total-order/stable-tie semantics). This is the
    # form the scan-based round loop consumes — the per-round keep is a
    # positional mask over the reordered buffer, so one full ordering serves
    # every halving ratio. ``None`` = XLA's stable sort.
    survivor_order: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None
    # Optional fused arm-loss estimator paths, keyed by estimator name
    # ("medoid_centrality", "build_delta", "swap_delta", ...). Each value is
    # a ``metric -> score-kernel`` factory; the estimator factories in
    # :mod:`repro.engine.estimators` consult this mapping first and fall back
    # to composing ``pairwise``/``centrality_sums``. This is how a backend
    # ships, say, an in-VMEM BUILD-delta kernel without any engine changes.
    fused_estimators: Mapping[str, Callable[[str], Callable]] = \
        field(default_factory=dict)


_REGISTRY: dict[str, DistanceBackend] = {}


def _ensure_plugins() -> None:
    """Pull in backend-registering packages that sit ABOVE this module in the
    layering (they import us, so they can't be imported at module scope).
    Called lazily from the resolvers — by the time anyone asks the registry
    for a name, importing :mod:`repro.quant` is cycle-free."""
    import repro.quant.backends  # noqa: F401  (registers quant_* backends)


def register_backend(backend: DistanceBackend) -> DistanceBackend:
    """Add ``backend`` to the registry (last registration wins on a name)."""
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(backend: Union[str, DistanceBackend, None]) -> DistanceBackend:
    """Resolve a backend name (or pass an instance through). ``None`` means
    the reference backend."""
    if backend is None:
        return _REGISTRY["reference"]
    if isinstance(backend, DistanceBackend):
        return backend
    if backend not in _REGISTRY:
        _ensure_plugins()
    try:
        return _REGISTRY[backend]
    except KeyError:
        raise ValueError(
            f"unknown backend {backend!r}; one of {list_backends()}") from None


def list_backends() -> tuple[str, ...]:
    _ensure_plugins()
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# built-in backends
# --------------------------------------------------------------------------

def _reference_centrality(metric: str) -> CentralityFn:
    def fn(x: jnp.ndarray, y: jnp.ndarray,
           ref_mask: jnp.ndarray | None = None) -> jnp.ndarray:
        return distances.centrality_sums(x, y, metric, ref_mask=ref_mask)
    return fn


def _pairwise_rowsum_centrality(metric: str) -> CentralityFn:
    kernel = kops.pairwise_kernel(metric)

    def fn(x: jnp.ndarray, y: jnp.ndarray,
           ref_mask: jnp.ndarray | None = None) -> jnp.ndarray:
        return distances.masked_rowsum(kernel(x, y), ref_mask)
    return fn


register_backend(DistanceBackend(
    name="reference",
    pairwise=distances.pairwise,
    centrality_sums=_reference_centrality,
    materializes_block=True,
    description="pure-jnp blocked distances (ground truth)",
))

register_backend(DistanceBackend(
    name="pallas_pairwise",
    pairwise=kops.pairwise_kernel,
    centrality_sums=_pairwise_rowsum_centrality,
    materializes_block=True,
    description="Pallas (C, R) block kernels + out-of-kernel row sum",
))

# The fused centrality kernels double as the fused ``medoid_centrality``
# estimator path (same contract: (x, y, ref_mask=) -> (C,) sums in-kernel).
_FUSED_ESTIMATORS = {"medoid_centrality": kops.centrality_kernel}

register_backend(DistanceBackend(
    name="pallas_fused",
    pairwise=kops.pairwise_kernel,
    centrality_sums=kops.centrality_kernel,
    materializes_block=False,
    description="fused in-kernel reference reduction (no (C, R) in HBM)",
    fused_estimators=_FUSED_ESTIMATORS,
))


def _topk_epilogue(theta: jnp.ndarray, keep: int) -> jnp.ndarray:
    return kops.kernel_topk_smallest(theta, keep=keep)


def _order_epilogue(theta: jnp.ndarray) -> jnp.ndarray:
    # The full ordering is the keep == C case of the rank/select kernel
    # pair: padded rows carry int32-max keys, so the first C slots are
    # exactly the real arms in stable ascending order.
    return kops.kernel_topk_smallest(theta, keep=theta.shape[0])


register_backend(DistanceBackend(
    name="pallas_fused_topk",
    pairwise=kops.pairwise_kernel,
    centrality_sums=kops.centrality_kernel,
    materializes_block=False,
    description="pallas_fused + on-chip top-k survivor-selection epilogue",
    survivor_topk=_topk_epilogue,
    survivor_order=_order_epilogue,
    fused_estimators=_FUSED_ESTIMATORS,
))
