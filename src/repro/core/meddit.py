"""Med-dit baseline [Bagaria et al. 2017]: UCB best-arm identification.

Direct bandit reduction — every pull of arm i draws an *independent* uniform
reference J and observes d(x_i, x_J). We implement the batched variant (B arms
pulled per step, each with its own independent reference), which preserves the
independent-sampling statistics the paper contrasts against while remaining
accelerator-friendly. Fixed-confidence stopping a la UCB for minimum
identification: stop when UCB(best) <= LCB(every other arm).

Reachable through the facade as ``repro.api.find_medoid(x, key,
algo="meddit")`` — UCB is a *different bandit strategy* (adaptive
per-arm pull counts, independent references), so unlike BUILD/SWAP it is an
alternative to the halving engine rather than an estimator plugged into it.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.distances import pairwise


class MedditResult(NamedTuple):
    medoid: jnp.ndarray   # scalar int32
    pulls: jnp.ndarray    # scalar int32, total distance computations
    means: jnp.ndarray    # (n,) final estimates


@functools.partial(jax.jit,
                   static_argnames=("metric", "batch", "init_pulls", "max_pulls"))
def meddit_medoid(
    data: jnp.ndarray,
    key: jax.Array,
    *,
    metric: str = "l2",
    sigma: float = 1.0,
    delta: float | None = None,
    batch: int = 64,
    init_pulls: int = 1,
    max_pulls: int = 0,   # 0 -> default n * 1000
) -> MedditResult:
    n = data.shape[0]
    dist = pairwise(metric)
    if delta is None:
        delta = 1.0 / n
    if max_pulls <= 0:
        max_pulls = n * 1000

    # --- initialization: init_pulls independent references per arm -----------
    key, sub = jax.random.split(key)
    refs0 = jax.random.randint(sub, (n, init_pulls), 0, n)
    # d(x_i, x_{refs0[i, k]}) for all i, k — blocked per init pull
    means = jnp.zeros((n,), jnp.float32)
    for k in range(init_pulls):
        r = refs0[:, k]
        # paired distances d(x_i, x_{r_i}) via row-wise metric
        vals = _paired_distance(data, data[r], metric)
        means = means + vals
    means = means / init_pulls
    counts = jnp.full((n,), init_pulls, jnp.float32)
    pulls0 = jnp.asarray(n * init_pulls, jnp.int32)

    log_term = jnp.log(2.0 * n / delta)

    def beta(c):
        return sigma * jnp.sqrt(2.0 * log_term / c)

    def stopped(means, counts):
        lcb = means - beta(counts)
        ucb = means + beta(counts)
        best = jnp.argmin(means)
        others_lcb = jnp.where(jnp.arange(n) == best, jnp.inf, lcb)
        return ucb[best] <= jnp.min(others_lcb)

    def cond(state):
        means, counts, key, pulls = state
        return (~stopped(means, counts)) & (pulls < max_pulls)

    def body(state):
        means, counts, key, pulls = state
        lcb = means - beta(counts)
        _, arms = jax.lax.top_k(-lcb, batch)          # B most promising arms
        key, sub = jax.random.split(key)
        refs = jax.random.randint(sub, (batch,), 0, n)  # independent references
        vals = _paired_distance(data[arms], data[refs], metric)
        c = counts[arms]
        means = means.at[arms].set((means[arms] * c + vals) / (c + 1.0))
        counts = counts.at[arms].add(1.0)
        return means, counts, key, pulls + batch

    means, counts, key, pulls = jax.lax.while_loop(
        cond, body, (means, counts, key, pulls0))
    return MedditResult(medoid=jnp.argmin(means).astype(jnp.int32),
                        pulls=pulls, means=means)


def _paired_distance(x: jnp.ndarray, y: jnp.ndarray, metric: str) -> jnp.ndarray:
    """Row-wise d(x_i, y_i) for x, y: (m, d) -> (m,)."""
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    if metric == "l1":
        return jnp.sum(jnp.abs(xf - yf), axis=-1)
    if metric == "sql2":
        return jnp.sum((xf - yf) ** 2, axis=-1)
    if metric == "l2":
        return jnp.sqrt(jnp.sum((xf - yf) ** 2, axis=-1))
    if metric == "cosine":
        num = jnp.sum(xf * yf, axis=-1)
        den = jnp.maximum(jnp.linalg.norm(xf, axis=-1)
                          * jnp.linalg.norm(yf, axis=-1), 1e-12)
        return 1.0 - num / den
    raise ValueError(f"unknown metric {metric!r}")
