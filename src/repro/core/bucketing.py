"""Shape bucketing for ragged multi-query medoid batches.

The ragged engine (:func:`repro.core.corr_sh.corr_sh_medoid_ragged`) traces
one XLA program per *static* ``(B, n_bucket, d, budget)`` signature. Real
query streams carry arbitrary per-query ``n``, so dispatching on raw shapes
would compile once per distinct ``n`` — unbounded. This module quantizes
``n`` to powers of two (with a small floor so tiny queries share one
program), which caps the number of distinct compilations for queries in
``[n_lo, n_hi]`` at ``ceil(log2(bucket(n_hi) / bucket(n_lo))) + 1``
regardless of how many distinct lengths arrive.

The service layer (:mod:`repro.launch.serve_medoid`) uses :func:`plan_buckets`
to coalesce queued queries into per-bucket groups and :func:`pack_queries`
to pad each group into the dense ``(B, n_bucket, d)`` + ``lengths`` form the
engine consumes.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Sequence

import jax.numpy as jnp

# Floor bucket size: every query with n <= 8 shares one compiled program.
# Also keeps degenerate schedules (n_bucket of 1 or 2) out of the hot path.
DEFAULT_MIN_BUCKET = 8


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    return 1 << (int(n) - 1).bit_length()


def bucket_n(n: int, min_bucket: int = DEFAULT_MIN_BUCKET) -> int:
    """The padded arm count a query of ``n`` points dispatches under."""
    if min_bucket < 1 or next_pow2(min_bucket) != min_bucket:
        raise ValueError(f"min_bucket must be a power of two, got {min_bucket}")
    return max(min_bucket, next_pow2(n))


def num_buckets_for_range(n_lo: int, n_hi: int,
                          min_bucket: int = DEFAULT_MIN_BUCKET) -> int:
    """Worst-case distinct buckets (== compilations) for queries whose sizes
    fall in ``[n_lo, n_hi]``: one per power of two between the two buckets."""
    lo = bucket_n(n_lo, min_bucket)
    hi = bucket_n(n_hi, min_bucket)
    return (hi // lo).bit_length()  # log2(hi/lo) + 1, both powers of two


def plan_buckets(lengths: Sequence[int],
                 min_bucket: int = DEFAULT_MIN_BUCKET) -> "OrderedDict[int, list[int]]":
    """Group query indices by bucket size, preserving arrival order.

    Returns ``{n_bucket: [query indices]}`` ordered by first arrival, so a
    FIFO scheduler that drains the first group services the oldest query
    first.
    """
    plan: "OrderedDict[int, list[int]]" = OrderedDict()
    for i, n in enumerate(lengths):
        plan.setdefault(bucket_n(int(n), min_bucket), []).append(i)
    return plan


def pack_queries(arrays: Sequence[jnp.ndarray],
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 pad_batch_to: int | None = None):
    """Pad a list of ``(n_i, d)`` query arrays into the ragged-engine form.

    Returns ``(data, lengths)`` with ``data: (B, n_bucket, d)`` zero-padded
    and ``lengths: (B,) int32``. All arrays must share ``d``. With
    ``pad_batch_to`` the batch dimension is padded with dummy length-1
    zero queries out to a fixed slot count, so a service dispatching variable
    group sizes still hits one compiled program per bucket.
    """
    if not arrays:
        raise ValueError("pack_queries needs at least one query")
    if arrays[0].ndim != 2:
        raise ValueError(
            f"all queries must be (n_i, d) arrays, got shape {arrays[0].shape}")
    d = arrays[0].shape[1]
    for a in arrays:
        if a.ndim != 2 or a.shape[1] != d:
            raise ValueError(
                f"all queries must be (n_i, {d}) arrays, got shape {a.shape}")
        if a.shape[0] < 1:
            raise ValueError("empty query (n == 0) — nothing to identify")
    nb = bucket_n(max(a.shape[0] for a in arrays), min_bucket)
    lengths = [a.shape[0] for a in arrays]
    rows = [jnp.pad(a, ((0, nb - a.shape[0]), (0, 0))) for a in arrays]
    if pad_batch_to is not None:
        if pad_batch_to < len(arrays):
            raise ValueError(
                f"pad_batch_to={pad_batch_to} < batch size {len(arrays)}")
        dummy = jnp.zeros((nb, d), rows[0].dtype)
        rows += [dummy] * (pad_batch_to - len(arrays))
        lengths += [1] * (pad_batch_to - len(lengths))
    return jnp.stack(rows), jnp.asarray(lengths, jnp.int32)
