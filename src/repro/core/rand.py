"""RAND baseline [Eppstein & Wang 2006]: non-adaptive uniform reference sampling.

Measures the distance between every point and a set of m reference points
chosen uniformly at random, then returns the empirical argmin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.distances import pairwise


@functools.partial(jax.jit, static_argnames=("num_refs", "metric", "replace"))
def rand_medoid(data: jnp.ndarray, key: jax.Array, *, num_refs: int,
                metric: str = "l2", replace: bool = True) -> jnp.ndarray:
    n = data.shape[0]
    if replace:
        refs = jax.random.randint(key, (num_refs,), 0, n)
    else:
        refs = jax.random.permutation(key, n)[:num_refs]
    theta_hat = jnp.mean(pairwise(metric)(data, data[refs]), axis=1)
    return jnp.argmin(theta_hat).astype(jnp.int32)
