"""Warn-once deprecation shims for the pre-``repro.api`` entry points.

The PR-4 facade (:mod:`repro.api`) is the documented surface; the old
per-module entry points keep working but emit one :class:`DeprecationWarning`
per process (Python's default warning registry dedupes per call site, which
under-reports across modules — the explicit set here makes "exactly once per
entry point" testable, see ``tests/test_api.py``)."""
from __future__ import annotations

import warnings

_WARNED: set[str] = set()


def warn_once(old: str, new: str) -> None:
    """Emit a DeprecationWarning for ``old`` (qualified name) once per
    process, pointing at its ``repro.api`` replacement."""
    if old in _WARNED:
        return
    _WARNED.add(old)
    warnings.warn(f"{old} is deprecated; use {new} instead",
                  DeprecationWarning, stacklevel=3)


def _reset_for_tests() -> None:
    _WARNED.clear()
