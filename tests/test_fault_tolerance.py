"""Watchdog, restart supervision, elastic mesh sizing."""
import pytest

from repro.runtime.fault_tolerance import (StepWatchdog, elastic_mesh_shape,
                                           run_with_restarts)


def test_watchdog_flags_straggler():
    wd = StepWatchdog(min_samples=8)
    for _ in range(20):
        assert not wd.record(1.0)
    assert wd.record(30.0)
    assert wd.stragglers == 1


def test_watchdog_tolerates_jitter():
    wd = StepWatchdog(min_samples=8)
    import random
    random.seed(0)
    flags = [wd.record(1.0 + random.random() * 0.02) for _ in range(50)]
    assert sum(flags) == 0


def test_run_with_restarts_resumes():
    crashes = {"n": 0}
    log = []

    def step(t):
        if t == 5 and crashes["n"] < 2:
            crashes["n"] += 1
            raise RuntimeError("node died")
        log.append(t)
        return t + 1

    def on_restart(t, exc):
        return 3   # "latest checkpoint"

    final = run_with_restarts(step, start_step=0, total_steps=10,
                              max_restarts=3, on_restart=on_restart)
    assert final == 10
    assert crashes["n"] == 2
    assert log.count(4) == 3   # steps 3-4 re-executed after both restarts


def test_run_with_restarts_gives_up():
    def step(t):
        raise RuntimeError("hard fail")

    with pytest.raises(RuntimeError):
        run_with_restarts(step, start_step=0, total_steps=3, max_restarts=1,
                          on_restart=lambda t, e: t)


def test_elastic_mesh_shape():
    assert elastic_mesh_shape(256, 16) == (16, 16)
    assert elastic_mesh_shape(192, 16) == (12, 16)   # lost a host: dp shrinks
    assert elastic_mesh_shape(100, 16) == (25, 4)    # tp degrades to fit
    assert elastic_mesh_shape(7, 16) == (7, 1)
