"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and finiteness (the assignment's required smoke
matrix). Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models.model import build_model
from repro.train.train_step import TrainCfg, init_train_state, make_train_step

S, B = 32, 2


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.num_audio_frames, cfg.d_model), dt)
    if cfg.family == "vlm":
        batch["image_embed"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), dt)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    tcfg = TrainCfg(peak_lr=1e-3, warmup_steps=2, total_steps=10, remat=True)
    state = init_train_state(model, jax.random.key(0), tcfg)
    batch = _batch(cfg, jax.random.key(1))

    loss, metrics = model.loss(state.params, batch, remat=False)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert float(loss) > 0

    step = jax.jit(make_train_step(model, tcfg))
    state2, m = step(state, batch)
    assert jnp.isfinite(m["loss"])
    assert int(state2.step) == 1
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0, f"{arch}: params did not update"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits, cache = model.prefill(params, batch, max_len=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = model.decode_step(params, tok, cache, S, batch=batch)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()
