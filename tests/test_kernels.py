"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _data(c, r, d, dtype, seed=0):
    k = jax.random.key(seed)
    x = jax.random.normal(jax.random.fold_in(k, 1), (c, d), dtype)
    y = jax.random.normal(jax.random.fold_in(k, 2), (r, d), dtype)
    return x, y


SHAPES = [(1, 1, 1), (5, 3, 2), (128, 128, 256), (130, 257, 300),
          (64, 512, 100), (333, 65, 129)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dot_kernel(shape, dtype):
    x, y = _data(*shape, dtype)
    got = ops.kernel_dot(x, y)
    want = ref.ref_dot_pairwise(x, y)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l1_kernel(shape, dtype):
    x, y = _data(*shape, dtype)
    got = ops.kernel_l1(x, y)
    want = ref.ref_l1_pairwise(x, y)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES)
def test_l1_centrality_fused(shape):
    x, y = _data(*shape, jnp.float32)
    got = ops.kernel_l1_centrality(x, y)
    want = ref.ref_l1_centrality(x, y)[:, 0] / y.shape[0]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("metric", ["l2", "sql2", "cosine"])
@pytest.mark.parametrize("shape", SHAPES[:4])
def test_gram_metrics(metric, shape):
    x, y = _data(*shape, jnp.float32)
    got = ops.pairwise_kernel(metric)(x, y)
    want = ref.ref_pairwise(metric, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@given(c=st.integers(1, 200), r=st.integers(1, 200), d=st.integers(1, 300),
       metric=st.sampled_from(["l1", "l2", "sql2", "cosine"]))
@settings(max_examples=25, deadline=None)
def test_kernels_hypothesis(c, r, d, metric):
    x, y = _data(c, r, d, jnp.float32, seed=c * 1000 + r)
    got = ops.pairwise_kernel(metric)(x, y)
    want = ref.ref_pairwise(metric, x, y)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    assert got.shape == (c, r)


@given(c=st.integers(1, 64), d=st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_distance_properties(c, d):
    """Metric axioms on the kernel outputs: symmetry + zero diagonal."""
    x, _ = _data(c, c, d, jnp.float32, seed=d)
    for metric in ("l1", "l2"):
        m = np.asarray(ops.pairwise_kernel(metric)(x, x))
        np.testing.assert_allclose(m, m.T, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.diag(m), 0.0, atol=1e-2)
        assert (m >= -1e-3).all()


# ------------------ fused top-k survivor-selection epilogue -----------------

@pytest.mark.parametrize("c,keep", [(1, 1), (5, 2), (8, 8), (128, 64),
                                    (130, 65), (257, 100), (300, 3),
                                    (513, 257)])
def test_topk_smallest_matches_lax_top_k(c, keep):
    """The on-chip rank/select pair must replicate jax.lax.top_k(-theta, k)
    bit-exactly — ascending values, stable index tie-break — because the
    round loop's survivor ORDER seeds the next round's gathers."""
    theta = jax.random.normal(jax.random.key(c * 7 + keep), (c,))
    got = ops.kernel_topk_smallest(theta, keep=keep)
    want = jax.lax.top_k(-theta, keep)[1]
    assert got.tolist() == want.tolist()


def test_topk_smallest_ties_and_inf():
    """Duplicate values and +inf entries (the ragged engine's masked arms)
    keep top_k's stable ordering."""
    theta = jnp.array([3.0, 1.0, jnp.inf, 1.0, 2.0, jnp.inf, 1.0, 0.5])
    got = ops.kernel_topk_smallest(theta, keep=6)
    want = jax.lax.top_k(-theta, 6)[1]
    assert got.tolist() == want.tolist() == [7, 1, 3, 6, 4, 0]


def test_topk_smallest_validates_keep():
    with pytest.raises(ValueError, match="keep"):
        ops.kernel_topk_smallest(jnp.zeros((4,)), keep=5)
    with pytest.raises(ValueError, match="keep"):
        ops.kernel_topk_smallest(jnp.zeros((4,)), keep=0)


@given(c=st.integers(1, 300), frac=st.integers(1, 100))
@settings(max_examples=25, deadline=None)
def test_topk_smallest_hypothesis(c, frac):
    keep = max(1, min(c, (c * frac) // 100))
    key = jax.random.key(c * 101 + frac)
    # quantized values force plenty of exact ties
    theta = jnp.round(jax.random.normal(key, (c,)) * 4.0) / 4.0
    got = ops.kernel_topk_smallest(theta, keep=keep)
    want = jax.lax.top_k(-theta, keep)[1]
    assert got.tolist() == want.tolist()
