"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _data(c, r, d, dtype, seed=0):
    k = jax.random.key(seed)
    x = jax.random.normal(jax.random.fold_in(k, 1), (c, d), dtype)
    y = jax.random.normal(jax.random.fold_in(k, 2), (r, d), dtype)
    return x, y


SHAPES = [(1, 1, 1), (5, 3, 2), (128, 128, 256), (130, 257, 300),
          (64, 512, 100), (333, 65, 129)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dot_kernel(shape, dtype):
    x, y = _data(*shape, dtype)
    got = ops.kernel_dot(x, y)
    want = ref.ref_dot_pairwise(x, y)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l1_kernel(shape, dtype):
    x, y = _data(*shape, dtype)
    got = ops.kernel_l1(x, y)
    want = ref.ref_l1_pairwise(x, y)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES)
def test_l1_centrality_fused(shape):
    x, y = _data(*shape, jnp.float32)
    got = ops.kernel_l1_centrality(x, y)
    want = ref.ref_l1_centrality(x, y)[:, 0] / y.shape[0]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("metric", ["l2", "sql2", "cosine"])
@pytest.mark.parametrize("shape", SHAPES[:4])
def test_gram_metrics(metric, shape):
    x, y = _data(*shape, jnp.float32)
    got = ops.pairwise_kernel(metric)(x, y)
    want = ref.ref_pairwise(metric, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@given(c=st.integers(1, 200), r=st.integers(1, 200), d=st.integers(1, 300),
       metric=st.sampled_from(["l1", "l2", "sql2", "cosine"]))
@settings(max_examples=25, deadline=None)
def test_kernels_hypothesis(c, r, d, metric):
    x, y = _data(c, r, d, jnp.float32, seed=c * 1000 + r)
    got = ops.pairwise_kernel(metric)(x, y)
    want = ref.ref_pairwise(metric, x, y)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    assert got.shape == (c, r)


@given(c=st.integers(1, 64), d=st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_distance_properties(c, d):
    """Metric axioms on the kernel outputs: symmetry + zero diagonal."""
    x, _ = _data(c, c, d, jnp.float32, seed=d)
    for metric in ("l1", "l2"):
        m = np.asarray(ops.pairwise_kernel(metric)(x, x))
        np.testing.assert_allclose(m, m.T, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.diag(m), 0.0, atol=1e-2)
        assert (m >= -1e-3).all()
