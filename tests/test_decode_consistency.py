"""Decode path == teacher-forced forward: the serving-correctness invariant.

For every family: run the full forward on S+1 tokens; then prefill on the
first S tokens and decode one step; the decode logits must match the
forward's position-S logits (within bf16 tolerance). This catches KV-cache
indexing bugs, RoPE offset bugs, and state-recurrence mismatches
(chunked-parallel vs step recurrence for SSM/xLSTM)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import encdec as ED
from repro.models import recurrent as R
from repro.models import transformer as T
from repro.models.model import build_model

S = 24
B = 2


def _batch(cfg, key, s):
    batch = {"tokens": jax.random.randint(key, (B, s), 0, cfg.vocab_size)}
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 9), (B, cfg.num_audio_frames, cfg.d_model), dt)
    if cfg.family == "vlm":
        batch["image_embed"] = jax.random.normal(
            jax.random.fold_in(key, 9), (B, cfg.num_image_tokens, cfg.d_model), dt)
    return batch


def _forward_logits(model, cfg, params, batch):
    if cfg.family in ("dense", "moe", "vlm"):
        logits, _, _ = T.transformer_forward(
            params, cfg, batch["tokens"], image_embed=batch.get("image_embed"))
        return logits
    if cfg.family == "ssm":
        logits, _ = R.xlstm_forward(params, cfg, batch["tokens"])
        return logits
    if cfg.family == "hybrid":
        logits, _ = R.hybrid_forward(params, cfg, batch["tokens"])
        return logits
    if cfg.family == "audio":
        enc = ED.encode(params, cfg, batch["frames"])
        logits, _ = ED.decode_train(params, cfg, batch["tokens"], enc)
        return logits
    raise ValueError(cfg.family)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    # f32 for a tight comparison (bf16 rounding differs between paths)
    cfg = cfg.scaled(dtype="float32")
    if cfg.moe is not None:
        # capacity-based dispatch drops tokens group-dependently, which is a
        # real (and accepted) train-vs-serve divergence; for the equivalence
        # test use a lossless capacity factor >= E/K so nothing drops.
        import dataclasses
        cfg = cfg.scaled(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    full = _batch(cfg, jax.random.key(1), S + 1)
    prompt = {k: (v[:, :S] if k == "tokens" else v) for k, v in full.items()}

    want = _forward_logits(model, cfg, params, full)[:, S - 1]  # predicts tok S
    logits_p, cache = model.prefill(params, prompt, max_len=S + 4)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(want),
                               rtol=2e-3, atol=2e-3)

    # now decode token S and compare against forward position S
    want2 = _forward_logits(model, cfg, params, full)[:, S]
    tok = full["tokens"][:, S]
    logits_d, _ = model.decode_step(params, tok, cache, S, batch=full)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(want2),
                               rtol=2e-3, atol=2e-3)
