"""Regression: v2's in-place survivor selection must keep exactly
``ceil(s_r / 2)`` arms when estimates tie at the k-th value.

The original code thresholded on the k-th *value* (``theta <= kth``), which
keeps every arm tied at the threshold — on integer/one-hot data that can be
far more than half, silently breaking the static round schedule. The fix
selects by membership in ``lax.top_k``'s index set, which breaks ties by
lower index exactly like the compact ``surv_idx`` path.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed_v2 import survivor_keep_mask


def test_keep_mask_exact_count_on_ties():
    # five arms tied at the threshold value 1.0; keep=3 must not keep all five
    theta = jnp.asarray([1.0, 1.0, 1.0, 1.0, 0.0, 2.0, 1.0, 3.0])
    keep = 3
    mask, order = survivor_keep_mask(theta, keep, 0, theta.shape[0])
    assert int(mask.sum()) == keep
    # old behavior for reference: value thresholding over-keeps
    kth = jax.lax.top_k(-theta, keep)[0][-1]
    assert int((theta <= -kth).sum()) == 6  # the bug this guards against
    # index tie-break: the smallest value first, then lowest-index ties
    np.testing.assert_array_equal(np.sort(np.asarray(order)), [0, 1, 4])
    np.testing.assert_array_equal(np.asarray(mask),
                                  [True, True, False, False, True,
                                   False, False, False])


def test_keep_mask_agrees_with_top_k_path():
    """The mask must be exactly the membership indicator of the top_k index
    set the compact path uses — sharded or not."""
    key = jax.random.key(0)
    # integer data -> duplicated estimate values
    theta = jax.random.randint(key, (64,), 0, 7).astype(jnp.float32)
    for keep in (1, 7, 32, 63):
        _, order = jax.lax.top_k(-theta, keep)
        want = np.zeros(64, bool)
        want[np.asarray(order)] = True
        # assemble the mask from 4 shards of 16 rows
        got = np.concatenate([
            np.asarray(survivor_keep_mask(theta, keep, off, 16)[0])
            for off in (0, 16, 32, 48)])
        np.testing.assert_array_equal(got, want)
        assert got.sum() == keep


def test_keep_mask_all_tied():
    theta = jnp.ones((32,))
    mask, order = survivor_keep_mask(theta, 16, 0, 32)
    assert int(mask.sum()) == 16
    # lowest indices win on a full tie
    np.testing.assert_array_equal(np.asarray(mask), np.arange(32) < 16)
