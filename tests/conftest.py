import os

# Smoke tests and benchmarks must see the real (single) CPU device — the
# 512-device XLA flag is set ONLY inside repro.launch.dryrun's own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
