"""Checkpoint manager: atomic commit, rotation, resume, elastic reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32),
                       "scale": jnp.ones((3,), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    shape = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got, meta = ckpt.restore(str(tmp_path), shape)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_rotation_keeps_newest(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, t, keep=3)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_crashed_writer_does_not_corrupt(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crash mid-write: stale tmp dir with garbage
    stale = tmp_path / "step_00000002.tmp"
    stale.mkdir()
    (stale / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 1   # tmp not visible
    shape = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got, meta = ckpt.restore(str(tmp_path), shape)
    assert meta["step"] == 1
    # and a new save over the stale tmp succeeds
    ckpt.save(str(tmp_path), 2, t)
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_restore_casts_dtype(tmp_path):
    t = {"w": jnp.ones((4, 4), jnp.float32)}
    ckpt.save(str(tmp_path), 0, t)
    shape = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    got, _ = ckpt.restore(str(tmp_path), shape)
    assert got["w"].dtype == jnp.bfloat16


def test_elastic_restore_with_sharding(tmp_path):
    """Restore onto an explicit (single-device) sharding — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = _tree(3)
    ckpt.save(str(tmp_path), 4, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), t)
    shape = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got, _ = ckpt.restore(str(tmp_path), shape, shardings=sh)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(t["w"]))
