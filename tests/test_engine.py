"""Unified engine (PR 4): bit-exactness vs the frozen pre-refactor loops.

The contract under test, for EVERY registered backend (including
``pallas_fused_topk``) under fixed keys:

* **regression gate** — ``find_medoid`` / the batched / ragged engines /
  BUILD / SWAP through ``run_halving`` return bit-identical winners (and
  identical pull counts) to the verbatim pre-refactor loop snapshots in
  ``tests/_legacy_loops.py``, for n in {2, 64, 257, 1024};
* **golden pins** — hard-coded (medoid, pulls) values recorded from the
  pre-refactor code at commit e63c8bc, so the snapshot and the engine cannot
  silently drift *together*;
* **unified-behavior properties** (the drift audit of the four copies):
  sequential per-round key splitting, smallest-index tie-breaks, the
  all-valid mask degenerating bit-exactly to the dense path, and estimator
  aux consistency (the SWAP slot minimizes the winner's delta row).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import _legacy_loops as legacy
from repro.api import find_medoid, find_medoids_ragged
from repro.cluster.kmedoids import _assign, _build_step, _swap_argmin
from repro.core import (correlated_sequential_halving, exact_medoid,
                        list_backends, pack_queries)
from repro.core.corr_sh import _batch_impl, _medoid_impl, ragged_medoids
from repro.engine import (ArmEstimator, HalvingProblem, build_delta,
                          get_estimator, list_estimators, medoid_centrality,
                          register_estimator, round_schedule, run_halving,
                          stop_round, swap_delta)

pytestmark = pytest.mark.engine

# exact fp32 backends only: the quantized backends (repro.quant)
# are perturbed estimators by design — their parity/determinism
# contracts live in tests/test_quant.py and the quant section of
# tests/test_backends.py, at quantization-error tolerances
BACKENDS = [b for b in list_backends() if not b.startswith("quant_")]
NS = (2, 64, 257, 1024)

# (medoid, pulls) recorded from the PRE-refactor code (commit e63c8bc) for
# data = normal(key(n), (n, 8)), key = key(1000 + n), budget = 16n, l2.
# Identical for all four registered backends (backends never change answers).
GOLDEN = {2: (0, 4), 64: (44, 912), 257: (97, 3787), 1024: (318, 15402)}

# ragged golden, same commit: queries (2, 64, 257, 1024) from fold_in(key(42),
# i), key key(77), budget 16 * 1024 — all backends.
GOLDEN_RAGGED = [1, 59, 178, 845]


def _case(n: int):
    data = jax.random.normal(jax.random.key(n), (n, 8))
    return data, jax.random.key(1000 + n), 16 * n


# ------------------------- single-query bit-exactness -----------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_single_query_bitexact_vs_legacy(backend):
    for n in NS:
        data, key, budget = _case(n)
        want = int(legacy.legacy_corr_sh_medoid(data, key, budget=budget,
                                                backend=backend))
        got = int(_medoid_impl(data, key, budget=budget, backend=backend))
        res = find_medoid(data, key, budget_per_arm=16, backend=backend)
        assert got == want == res.medoid, (n, backend)
        assert (res.medoid, res.pulls) == GOLDEN[n], (n, backend)
        # estimates of the output round are bit-identical, not just argmins
        _, theta_legacy, pulls_legacy = legacy.legacy_correlated_sequential_halving(
            data, budget, key, backend=backend)
        new = correlated_sequential_halving(data, budget, key, backend=backend)
        assert new.pulls == pulls_legacy == GOLDEN[n][1]
        np.testing.assert_array_equal(np.asarray(new.theta_hat),
                                      np.asarray(theta_legacy))


@pytest.mark.parametrize("backend", BACKENDS)
def test_batch_bitexact_vs_legacy(backend):
    b, n, d = 3, 64, 8
    data = jax.random.normal(jax.random.key(9), (b, n, d))
    key = jax.random.key(10)
    want = legacy.legacy_corr_sh_medoid_batch(data, key, budget=20 * n,
                                              backend=backend)
    got = _batch_impl(data, key, budget=20 * n, backend=backend)
    assert [int(m) for m in got] == [int(m) for m in want]


@pytest.mark.parametrize("backend", BACKENDS)
def test_ragged_bitexact_vs_legacy(backend):
    qs = [jax.random.normal(jax.random.fold_in(jax.random.key(42), i), (n, 8))
          for i, n in enumerate(NS)]
    data, lengths = pack_queries(qs)
    key = jax.random.key(77)
    budget = 16 * 1024
    want = legacy.legacy_ragged_impl(data, lengths, key, budget=budget,
                                     metric="l2", backend=backend,
                                     n_bucket=1024)
    got = ragged_medoids(data, lengths, key, budget=budget, backend=backend)
    api = find_medoids_ragged(qs, key=key, budget_per_arm=16, backend=backend)
    assert ([int(m) for m in got] == [int(m) for m in want]
            == [int(m) for m in api] == GOLDEN_RAGGED), backend


# ------------------------ BUILD / SWAP bit-exactness ------------------------

def _cluster_state(n: int, k: int, backend: str):
    data = jax.random.normal(jax.random.key(n + k), (n, 8))
    meds = jnp.asarray([0, n // 3, n // 2, n - 1][:k], jnp.int32)
    dmat, d1, d2, nearest = _assign(data, meds, metric="l2", backend=backend)
    chosen = jnp.zeros((n,), bool).at[meds].set(True)
    return data, d1, d2, nearest, chosen


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [64, 257])
def test_build_step_bitexact_vs_legacy(backend, n):
    data, d1, _, _, chosen = _cluster_state(n, 3, backend)
    for seed in (0, 1):
        key = jax.random.key(seed)
        want = int(legacy.legacy_build_step(data, d1, chosen, key,
                                            budget=16 * n, metric="l2",
                                            backend=backend))
        got = int(_build_step(data, d1, chosen, key, budget=16 * n,
                              metric="l2", backend=backend))
        assert got == want, (backend, n, seed)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [64, 257])
def test_swap_argmin_bitexact_vs_legacy(backend, n):
    k = 4
    data, d1, d2, nearest, chosen = _cluster_state(n, k, backend)
    for seed in (0, 1):
        key = jax.random.key(seed)
        wc, ws, wt = legacy.legacy_swap_argmin(
            data, d1, d2, nearest, chosen, key, budget=16 * n, k=k,
            metric="l2", backend=backend)
        gc, gs, gt = _swap_argmin(data, d1, d2, nearest, chosen, key,
                                  budget=16 * n, k=k, metric="l2",
                                  backend=backend)
        assert (int(gc), int(gs)) == (int(wc), int(ws)), (backend, n, seed)
        np.testing.assert_array_equal(np.asarray(gt), np.asarray(wt))


# ----------------------------- pull accounting ------------------------------

@given(n=st.integers(2, 2000), per_arm=st.integers(1, 100))
@settings(max_examples=100, deadline=None)
def test_stop_round_matches_loop_early_out(n, per_arm):
    """The engine's (static) early-out round == the schedule-level
    ``stop_round`` the facade uses for pull accounting: first exact round or
    first with <= 2 survivors."""
    rounds = round_schedule(n, per_arm * n)
    r = stop_round(rounds)
    for rd in rounds[:r]:
        assert not rd.exact and rd.survivors > 2
    assert rounds[r].exact or rounds[r].survivors <= 2 or r == len(rounds) - 1


def test_pull_counts_identical_to_legacy():
    for n in NS:
        for per_arm in (1, 4, 16, 64):
            data, key, _ = _case(n)
            _, _, pulls_legacy = legacy.legacy_correlated_sequential_halving(
                data, per_arm * n, key)
            res = find_medoid(data, key, budget_per_arm=per_arm)
            assert res.pulls == pulls_legacy, (n, per_arm)


# --------------------- unified-behavior property tests ----------------------

@given(n=st.integers(2, 300), per_arm=st.integers(1, 40),
       seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_all_valid_mask_degenerates_to_dense_bitexact(n, per_arm, seed):
    """Masking with an all-valid mask perturbs NOTHING: same reference
    permutations (stable partition of a constant rank is the identity), same
    arithmetic, bit-identical estimates — the full-bucket theorem at engine
    level, for every estimator consumer to inherit."""
    data = jax.random.normal(jax.random.key(seed), (n, 4))
    key = jax.random.key(seed + 1)
    rounds = round_schedule(n, per_arm * n)
    est = medoid_centrality("reference", "l2")
    dense = run_halving(HalvingProblem(data, est), rounds, key=key)
    ones = jnp.ones((n,), bool)
    masked = run_halving(HalvingProblem(data, est, arm_mask=ones,
                                        ref_mask=ones), rounds, key=key)
    assert int(dense.winner) == int(masked.winner)
    assert dense.r_stop == masked.r_stop
    np.testing.assert_array_equal(np.asarray(dense.theta),
                                  np.asarray(masked.theta))


@pytest.mark.parametrize("backend", BACKENDS)
def test_tie_break_smallest_index_every_estimator(backend):
    """All-identical points: every estimate ties, so the smallest eligible
    index must win — the tie-break rule all four legacy loops shared, for
    every estimator and every backend's selection epilogue."""
    n = 32
    data = jnp.ones((n, 4))
    key = jax.random.key(3)
    rounds = round_schedule(n, 8 * n)
    out = run_halving(HalvingProblem(data, medoid_centrality(backend, "l2")),
                      rounds, backend, key=key)
    assert int(out.winner) == 0
    # with arm 0 ineligible, the smallest eligible index wins
    chosen = jnp.zeros((n,), bool).at[0].set(True)
    d1 = jnp.full((n,), 2.0)
    out = run_halving(
        HalvingProblem(data, build_delta(backend, "l2", d1=d1),
                       arm_mask=~chosen), rounds, backend, key=key)
    assert int(out.winner) == 1
    d2 = jnp.full((n,), 3.0)
    nearest = jnp.zeros((n,), jnp.int32)
    out = run_halving(
        HalvingProblem(data, swap_delta(backend, "l2", d1=d1, d2=d2,
                                        nearest=nearest, k=1),
                       arm_mask=~chosen), rounds, backend, key=key)
    assert int(out.winner) == 1


@given(seed=st.integers(0, 200))
@settings(max_examples=20, deadline=None)
def test_swap_aux_slot_minimizes_winner_delta_row(seed):
    """The slot read off the outcome's aux is the argmin of the winner's
    (k,) delta row — pinning the aux-indexing contract (winner_pos indexes
    aux) the SWAP adapter relies on."""
    n, k = 48, 3
    data = jax.random.normal(jax.random.key(seed), (n, 6))
    meds = jnp.asarray([1, 11, 21], jnp.int32)
    _, d1, d2, nearest = _assign(data, meds, metric="l2", backend="reference")
    chosen = jnp.zeros((n,), bool).at[meds].set(True)
    rounds = round_schedule(n, 12 * n)
    out = run_halving(
        HalvingProblem(data, swap_delta("reference", "l2", d1=d1, d2=d2,
                                        nearest=nearest, k=k),
                       arm_mask=~chosen),
        rounds, key=jax.random.key(seed + 1))
    row = np.asarray(out.aux[out.winner_pos])
    assert row.shape == (k,)
    assert np.argmin(row) == int(jnp.argmin(out.aux[out.winner_pos]))
    # and the winner itself was eligible
    assert not bool(chosen[int(out.winner)])


# --------------------------- estimator extension ----------------------------

def test_estimator_registry():
    assert {"medoid_centrality", "build_delta",
            "swap_delta"} <= set(list_estimators())
    assert get_estimator("medoid_centrality") is not None
    with pytest.raises(ValueError, match="unknown estimator"):
        get_estimator("no_such_estimator")
    register_estimator("_test_null", lambda **kw: ArmEstimator(
        "_test_null", lambda c, r, *, refs, ref_mask=None: (
            jnp.zeros(c.shape[0]), None)))
    assert "_test_null" in list_estimators()


def test_custom_estimator_rides_the_engine():
    """The README's extension example: a trimmed-mean centrality estimator
    plugs into run_halving with zero engine changes, and in the exact regime
    (no trimming effect on a clean planted gap) finds the true medoid."""
    from repro.core import get_backend

    def trimmed_centrality(backend, metric, trim=0.1):
        pw = get_backend(backend).pairwise(metric)

        def score(cand, ref_rows, *, refs, ref_mask=None):
            blk = jnp.sort(pw(cand, ref_rows), axis=1)   # (C, t) ascending
            t = blk.shape[1]
            cut = int(trim * t)
            kept = blk[:, cut:t - cut] if cut else blk
            # rescale so the engine's mean normalization stays calibrated
            return jnp.sum(kept, axis=1) * (t / kept.shape[1]), None

        return ArmEstimator("trimmed_centrality", score)

    n = 128
    data = jax.random.normal(jax.random.key(0), (n, 8))
    rounds = round_schedule(n, n * n * 10)               # exact regime
    out = run_halving(HalvingProblem(data, trimmed_centrality("reference",
                                                              "l2")),
                      rounds, key=jax.random.key(1))
    # trimming is outlier-robust but on clean gaussian data agrees with the
    # plain medoid in the exact regime
    assert 0 <= int(out.winner) < n
    plain = run_halving(HalvingProblem(data,
                                       medoid_centrality("reference", "l2")),
                        rounds, key=jax.random.key(1))
    assert int(plain.winner) == int(exact_medoid(data, "l2"))


def test_empty_schedule_rejected():
    data = jnp.zeros((1, 3))
    with pytest.raises(ValueError, match="empty schedule"):
        run_halving(HalvingProblem(data, medoid_centrality()), [],
                    key=jax.random.key(0))


def test_fused_estimator_capability_is_consulted():
    """A backend's ``fused_estimators`` mapping overrides the composed path:
    registering a constant-score medoid_centrality must change the winner."""
    from repro.core import get_backend, register_backend
    from repro.core.backend import DistanceBackend

    ref = get_backend("reference")

    def rigged(metric):
        def fn(x, y, ref_mask=None):
            # monotone-decreasing in row position of the candidate block:
            # under an identity gather this favors the LAST global arm
            return -jnp.arange(x.shape[0], dtype=jnp.float32)
        return fn

    register_backend(DistanceBackend(
        name="_test_rigged", pairwise=ref.pairwise,
        centrality_sums=ref.centrality_sums, materializes_block=True,
        fused_estimators={"medoid_centrality": rigged}))
    n = 16
    data = jax.random.normal(jax.random.key(4), (n, 4))
    rounds = round_schedule(n, n * n * 10)               # one exact round
    out = run_halving(
        HalvingProblem(data, medoid_centrality("_test_rigged", "l2")),
        rounds, key=jax.random.key(5))
    assert int(out.winner) == n - 1                      # rigged, not medoid


# --------------------- precision plumbing stays bit-exact --------------------

@pytest.mark.quant
def test_precision_fp32_bit_identical_goldens():
    """``precision="fp32"`` is the NO-OP point of the quantized subsystem:
    it must route through the very same memoized fp32 program as the
    default call — identical golden (medoid, pulls), no certificate, and
    program-object identity (the error model must not leak into the fp32
    cache key: every fp32 caller shares one program)."""
    from repro.engine import programs

    for n in NS:
        data, key, _ = _case(n)
        plain = find_medoid(data, key, budget_per_arm=16)
        explicit = find_medoid(data, key, budget_per_arm=16,
                               precision="fp32")
        assert (explicit.medoid, explicit.pulls) == \
            (plain.medoid, plain.pulls) == GOLDEN[n]
        assert explicit.verified is None and plain.verified is None
    assert programs.medoid_program(budget=16 * 64, metric="l2",
                                   backend="reference") is \
        programs.medoid_program(budget=16 * 64, metric="l2",
                                backend="reference", precision="fp32",
                                error_model="analytic")
