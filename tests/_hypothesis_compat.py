"""Tiny local stand-in for ``hypothesis`` so tier-1 collects everywhere.

The container this repo is verified in does not ship ``hypothesis``; four test
modules use it for property-style sweeps. Importing from this module instead of
``hypothesis`` keeps those tests running in both worlds:

* when ``hypothesis`` IS installed, its real ``given``/``settings``/strategies
  are re-exported unchanged (full shrinking, example database, etc.);
* when it is absent, a deterministic fallback runs each property over a fixed,
  seed-derived set of examples: the strategy bounds (the classic edge cases)
  first, then pseudo-random interior points drawn from a PRNG seeded by the
  test name — stable across runs and machines, no external deps.

Only the strategy surface the test-suite uses is implemented (``integers``,
``sampled_from``, ``floats``, ``booleans``). Add more as tests need them.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import zlib

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    # Cap on examples per property in fallback mode. Hypothesis amortizes its
    # example count over shrinking; a plain sweep doesn't need hundreds of
    # draws to catch shape/edge bugs, and jit-heavy properties recompile per
    # distinct shape. Override with REPRO_COMPAT_MAX_EXAMPLES.
    _MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_COMPAT_MAX_EXAMPLES", "10"))

    class _Strategy:
        """Deterministic example source mirroring a hypothesis strategy."""

        def __init__(self, boundary, draw):
            self._boundary = list(boundary)  # always-tried edge cases
            self._draw = draw                # rng -> interior example

        def examples(self, rng: random.Random, count: int) -> list:
            out = list(self._boundary[:count])
            while len(out) < count:
                out.append(self._draw(rng))
            return out

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            edges = [min_value, max_value]
            if max_value - min_value > 1:
                edges.append(min_value + 1)
            return _Strategy(edges, lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            elems = list(elements)
            return _Strategy(elems, lambda r: r.choice(elems))

        @staticmethod
        def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
            return _Strategy([min_value, max_value],
                             lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy([False, True], lambda r: r.random() < 0.5)

    st = _Strategies()

    def settings(max_examples: int = 100, **_ignored):
        """Record ``max_examples`` for ``given`` to pick up; other hypothesis
        knobs (deadline, phases, ...) have no fallback meaning and are ignored."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            n_ex = min(getattr(fn, "_compat_max_examples", 100),
                       _MAX_EXAMPLES_CAP)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # Stable per-test seed: same examples on every run/machine.
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                pos_ex = [s.examples(rng, n_ex) for s in pos_strategies]
                kw_ex = {k: s.examples(rng, n_ex)
                         for k, s in kw_strategies.items()}
                for i in range(n_ex):
                    drawn_pos = [ex[i] for ex in pos_ex]
                    drawn_kw = {k: ex[i] for k, ex in kw_ex.items()}
                    try:
                        fn(*args, *drawn_pos, **kwargs, **drawn_kw)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example ({i + 1}/{n_ex}): "
                            f"args={drawn_pos} kwargs={drawn_kw}") from e

            # The strategy-filled parameters are supplied here, not by
            # pytest — hide them so they aren't mistaken for fixtures.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
