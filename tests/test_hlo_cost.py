"""Loop-aware HLO cost model: validated against analytically-known programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import hlo_cost
from repro.roofline.analysis import parse_collectives


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_single_matmul():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 32), jnp.float32))
    r = hlo_cost.analyze(c.as_text())
    assert r.dot_flops == 2 * 64 * 128 * 32


def test_scan_multiplies_trip_count():
    A = jnp.zeros((128, 128))

    def f(x):
        def body(c, _):
            return c @ A, 0
        y, _ = jax.lax.scan(body, x, jnp.arange(13))
        return y

    r = hlo_cost.analyze(_compile(f, jax.ShapeDtypeStruct((8, 128), jnp.float32)).as_text())
    assert r.dot_flops == 13 * 2 * 8 * 128 * 128
    assert r.unknown_while == 0


def test_nested_scan():
    A = jnp.zeros((64, 64))

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ A, 0
            y, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return y, 0
        y, _ = jax.lax.scan(outer, x, jnp.arange(5))
        return y

    r = hlo_cost.analyze(_compile(f, jax.ShapeDtypeStruct((4, 64), jnp.float32)).as_text())
    assert r.dot_flops == 15 * 2 * 4 * 64 * 64


def test_xla_cost_analysis_undercounts_loops():
    """The reason hlo_cost exists: XLA counts while bodies once."""
    A = jnp.zeros((128, 128))

    def f(x):
        def body(c, _):
            return c @ A, 0
        y, _ = jax.lax.scan(body, x, jnp.arange(10))
        return y

    c = _compile(f, jax.ShapeDtypeStruct((8, 128), jnp.float32))
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    xla = float(ca.get("flops", 0))
    ours = hlo_cost.analyze(c.as_text()).dot_flops
    assert ours >= 9 * xla   # ~10x


def test_batched_dot_flops():
    c = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                 jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
                 jax.ShapeDtypeStruct((4, 16, 32), jnp.float32))
    r = hlo_cost.analyze(c.as_text())
    assert r.dot_flops == 2 * 4 * 8 * 16 * 32


def test_collective_parse_shapes():
    txt = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%p), to_apply=%add
  %ag = bf16[2048]{0} all-gather(%p), dimensions={0}
  ROOT %r = f32[16]{0} copy(%p)
}
"""
    st = parse_collectives(txt)
    assert st.bytes_by_kind["all-reduce"] == 2 * 1024 * 512 * 4
    assert st.bytes_by_kind["all-gather"] == 2048 * 2
