"""Bandit k-medoids subsystem: recovery, PAM parity, ragged reuse, backends.

The contract under test:

* **recovery** — planted clusters are recovered (ARI >= 0.95) with >= 10x
  fewer distance evaluations than exact PAM's ``n^2`` (the acceptance cell
  runs the real CLI entry point at n=4096);
* **parity** — in the exact-reference regime (t_r == n) the bandit BUILD
  equals exact greedy BUILD step for step, and the bandit SWAP converges to
  exact PAM's medoid set; a k=1 BUILD and a full-bucket single-cluster
  refinement step are *bit-identical* to ``corr_sh_medoid``;
* **ragged reuse** — per-cluster subproblems ride the bucketed ragged
  engine: the compile odometer stays within the bucket bound and a second
  sweep with the same shape traffic compiles NOTHING new;
* **backends** — every registered backend returns identical medoids and
  labels for a fixed key.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (adjusted_rand_index, bandit_kmedoids,
                           kmedoids_via_service, make_direct_refiner,
                           pam_build, pam_exact, pam_pulls)
from repro.cluster.pam_exact import distance_matrix
from repro.core import (bucket_n, corr_sh_medoid, list_backends,
                        num_buckets_for_range, ragged_compile_count)
from repro.data.medoid_datasets import (CLUSTER_DATASETS, planted_clusters,
                                        rnaseq_clusters, uneven_sizes)

pytestmark = pytest.mark.cluster

# exact fp32 backends only: the quantized backends (repro.quant)
# are perturbed estimators by design — their parity/determinism
# contracts live in tests/test_quant.py and the quant section of
# tests/test_backends.py, at quantization-error tolerances
BACKENDS = [b for b in list_backends() if not b.startswith("quant_")]


def _exact_budget(n: int) -> int:
    """Per-arm budget putting every round in the exact regime (t_r == n)."""
    return n * max(1, math.ceil(math.log2(n)))


# ------------------------------- metrics -----------------------------------

def test_ari_semantics():
    a = [0, 0, 1, 1, 2, 2]
    assert adjusted_rand_index(a, a) == 1.0
    assert adjusted_rand_index(a, [2, 2, 0, 0, 1, 1]) == 1.0   # relabeling
    assert adjusted_rand_index(a, [0, 1, 0, 1, 0, 1]) < 0.5
    with pytest.raises(ValueError, match="same points"):
        adjusted_rand_index([0, 1], [0, 1, 2])


def test_uneven_sizes_are_heterogeneous():
    sizes = uneven_sizes(700, 4)
    assert sum(sizes) == 700 and all(s >= 1 for s in sizes)
    # spans multiple power-of-two buckets: the ragged traffic property
    assert len({bucket_n(s) for s in sizes}) >= 2


def test_uneven_sizes_every_cluster_nonempty():
    """The clamp-and-rebalance never yields an empty cluster, even at
    k ~ n (regression: the overshoot used to be dumped on the last entry,
    driving it to zero)."""
    for n in (2, 17, 26, 64, 123):
        for k in (1, 2, n // 2, n - 1, n):
            if k < 1:
                continue
            sizes = uneven_sizes(n, k)
            assert sum(sizes) == n and len(sizes) == k
            assert all(s >= 1 for s in sizes), (n, k, sizes)


# ------------------------------ recovery -----------------------------------

def test_planted_recovery_and_invariants():
    key = jax.random.key(0)
    data, labels = planted_clusters(jax.random.fold_in(key, 1), 300,
                                    d=16, k=4)
    res = bandit_kmedoids(data, 4, jax.random.fold_in(key, 2))
    assert adjusted_rand_index(res.labels, labels) >= 0.95
    assert len(res.medoids) == 4 and len(set(res.medoids)) == 4
    assert res.labels.shape == (300,)
    assert set(np.unique(res.labels)) <= set(range(4))
    # each medoid is assigned to its own slot, and total pulls add up
    assert res.labels[res.medoids].tolist() == [0, 1, 2, 3]
    assert res.pulls == (res.build_pulls + res.assign_pulls
                         + res.refine_pulls + res.swap_pulls)
    assert res.cost > 0.0


@pytest.mark.parametrize("dataset", sorted(CLUSTER_DATASETS))
def test_planted_recovery_all_dataset_flavors(dataset):
    metric, gen = CLUSTER_DATASETS[dataset]
    key = jax.random.key(3)
    data, labels = gen(jax.random.fold_in(key, 1), 320, 128, 4)
    res = bandit_kmedoids(data, 4, jax.random.fold_in(key, 2), metric=metric)
    assert adjusted_rand_index(res.labels, labels) >= 0.95, dataset


def test_acceptance_rnaseq_4096_recovery_and_pull_gap():
    """The PR's acceptance cell, through the CLI's run(): k=8 on rnaseq-like
    n=4096 recovers the planted clusters with >= 10x fewer distance
    computations than exact PAM (whose pull count is n^2 by construction)."""
    from repro.launch.kmedoids import run

    out = run(4096, 128, 8, "rnaseq_like", seed=0)
    assert out["ari"] >= 0.95
    assert out["pam_pulls"] == pam_pulls(4096) == 4096 * 4096
    assert out["pulls"] * 10 <= out["pam_pulls"]
    assert out["pulls_ratio"] >= 10.0


# ----------------------- parity vs exact PAM -------------------------------

def test_build_parity_vs_exact_greedy():
    """Exact-regime budgets (t_r == n): bandit BUILD's greedy choices equal
    exact PAM BUILD's, step for step (order matters)."""
    n, k = 64, 4
    data, _ = planted_clusters(jax.random.key(5), n, d=8, k=k)
    res = bandit_kmedoids(data, k, jax.random.key(6),
                          build_budget_per_arm=_exact_budget(n),
                          refine_sweeps=0, max_swap_rounds=0)
    want, _ = pam_build(distance_matrix(data, "l2"), k)
    assert res.medoids == want


def test_swap_parity_vs_exact_pam():
    """Exact-regime BUILD + SWAP converge to exact PAM's medoid set."""
    n, k = 64, 3
    data, _ = planted_clusters(jax.random.key(7), n, d=8, k=k)
    res = bandit_kmedoids(data, k, jax.random.key(8),
                          build_budget_per_arm=_exact_budget(n),
                          swap_budget_per_arm=_exact_budget(n),
                          refine_sweeps=0, max_swap_rounds=32)
    pam = pam_exact(data, k, "l2")
    assert sorted(res.medoids) == sorted(pam.medoids)
    assert res.cost == pytest.approx(pam.cost, rel=1e-4)


def test_k1_build_is_bit_identical_to_corr_sh_medoid():
    """k=1 collapses to the paper's problem: BUILD literally calls the same
    jitted ``corr_sh_medoid`` with the documented derived key."""
    n = 128
    data = jax.random.normal(jax.random.key(9), (n, 8))
    key = jax.random.key(10)
    res = bandit_kmedoids(data, 1, key, refine_sweeps=0, max_swap_rounds=0,
                          build_budget_per_arm=16)
    step0_key = jax.random.fold_in(jax.random.fold_in(key, 0), 0)
    want = int(corr_sh_medoid(data, step0_key, budget=16 * n))
    assert res.medoids == [want]


@pytest.mark.parametrize("backend", BACKENDS)
def test_full_bucket_single_cluster_refine_is_bit_identical(backend):
    """A single cluster exactly filling its power-of-two bucket goes through
    the ragged engine bit-identically to ``corr_sh_medoid`` — the full-bucket
    theorem applied to clustering's refinement traffic."""
    n, bpa = 256, 20
    data = jax.random.normal(jax.random.key(11), (n, 12))
    key = jax.random.key(12)
    refiner = make_direct_refiner(metric="l2", backend=backend,
                                  budget_per_arm=bpa)
    locals_, pulls = refiner([data], key)
    slot_key = jax.random.split(jax.random.fold_in(key, n), 1)[0]
    want = int(corr_sh_medoid(data, slot_key, budget=bpa * n,
                              backend=backend))
    assert locals_ == [want]
    assert pulls > 0


# -------------------- ragged schedule reuse (odometer) ---------------------

def test_refiner_compile_odometer_bound_and_reuse():
    """Heterogeneous cluster sizes compile at most one program per bucket,
    and a second sweep with the same shape traffic compiles NOTHING."""
    key = jax.random.key(13)
    sizes = (9, 33, 70, 200)       # buckets 16, 64, 128, 256
    arrays = [jax.random.normal(jax.random.fold_in(key, i), (s, 6))
              for i, s in enumerate(sizes)]
    refiner = make_direct_refiner(metric="l2", backend="reference",
                                  budget_per_arm=12)
    c0 = ragged_compile_count()
    refiner(arrays, jax.random.fold_in(key, 100))
    first = ragged_compile_count() - c0
    assert first <= num_buckets_for_range(min(sizes), max(sizes))
    refiner(arrays, jax.random.fold_in(key, 101))      # fresh keys, same shapes
    assert ragged_compile_count() - c0 == first        # zero new programs


def test_pipeline_compile_odometer_second_run_free():
    """End-to-end: replaying the pipeline compiles NOTHING new (the pow2
    bucket + batch-slot padding keeps every shape out of the jit cache key),
    and a different key can only add programs within the bucket-range bound
    (cluster sizes may drift across bucket boundaries, buckets can't
    multiply)."""
    key = jax.random.key(14)
    data, _ = planted_clusters(jax.random.fold_in(key, 1), 260, d=8, k=4)
    bandit_kmedoids(data, 4, jax.random.fold_in(key, 2), refine_sweeps=2)
    c0 = ragged_compile_count()
    bandit_kmedoids(data, 4, jax.random.fold_in(key, 2), refine_sweeps=2)
    assert ragged_compile_count() - c0 == 0
    bandit_kmedoids(data, 4, jax.random.fold_in(key, 3), refine_sweeps=2)
    assert ragged_compile_count() - c0 <= num_buckets_for_range(1, 260)


# ------------------------------ backends -----------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_backends_identical_medoids_and_labels(backend):
    """Backends change memory traffic, never answers: a fixed key produces
    the same medoid set and the same labeling under every backend."""
    key = jax.random.key(15)
    data, _ = planted_clusters(jax.random.fold_in(key, 1), 200, d=16, k=3)
    res = bandit_kmedoids(data, 3, jax.random.fold_in(key, 2),
                          backend=backend)
    ref = bandit_kmedoids(data, 3, jax.random.fold_in(key, 2),
                          backend="reference")
    assert res.medoids == ref.medoids
    assert res.labels.tolist() == ref.labels.tolist()


# ------------------------------- service -----------------------------------

def test_refinement_through_medoid_server():
    """The service route answers the per-cluster subproblems through the
    continuous-batching MedoidServer and still recovers the clusters."""
    key = jax.random.key(16)
    data, labels = planted_clusters(jax.random.fold_in(key, 1), 300,
                                    d=16, k=4)
    res, srv = kmedoids_via_service(data, 4, jax.random.fold_in(key, 2))
    assert adjusted_rand_index(res.labels, labels) >= 0.95
    stats = srv.stats()
    assert stats["answered"] >= 4          # one query per refined cluster
    assert stats["pending"] == 0
    assert stats["recompiles"] <= stats["distinct_buckets"]
    assert res.refine_pulls > 0


# ------------------------------ validation ---------------------------------

def test_degenerate_n1_and_k_equals_n():
    """n=1 and k=n have no swap candidates — the pipeline must not crash
    (regression: the SWAP argmin used to hit an empty round schedule)."""
    res = bandit_kmedoids(jnp.zeros((1, 3)), 1, jax.random.key(0))
    assert res.medoids == [0] and res.labels.tolist() == [0]
    data = jax.random.normal(jax.random.key(1), (5, 3))
    res = bandit_kmedoids(data, 5, jax.random.key(2))
    assert sorted(res.medoids) == [0, 1, 2, 3, 4]
    # Gram-trick self-distances are ~sqrt(eps), not exactly zero
    assert res.cost == pytest.approx(0.0, abs=1e-2)


def test_input_validation():
    data = jnp.zeros((10, 3))
    with pytest.raises(ValueError, match="1 <= k"):
        bandit_kmedoids(data, 0, jax.random.key(0))
    with pytest.raises(ValueError, match="1 <= k"):
        bandit_kmedoids(data, 11, jax.random.key(0))
    with pytest.raises(ValueError, match="expected"):
        bandit_kmedoids(jnp.zeros((10,)), 2, jax.random.key(0))
    with pytest.raises(ValueError, match="unknown backend"):
        bandit_kmedoids(data, 2, jax.random.key(0), backend="nope")
    with pytest.raises(ValueError, match="1 <= k"):
        pam_exact(np.zeros((4, 2)), 5)
