"""Ragged multi-query engine: schedule properties, bucketing, and parity.

The contract under test: ``corr_sh_medoid_ragged`` answers a padded
``(B, n_max, d)`` batch with per-query ``lengths`` through ONE shared static
schedule, yet

* a query occupying its full power-of-two bucket is *bit-identical* to the
  single-query engine run with the same derived key (masking with an
  all-valid mask perturbs nothing), and
* any query given an exact-regime budget recovers the true medoid — so on
  mixed-n batches ragged and the per-query loop agree query-for-query, for
  every registered backend.

Plus the property harness for ``round_schedule`` (the satellite of this PR):
pull ceiling, halving-to-one, exact-flag characterization, budget
monotonicity — deterministic fallback sweeps when hypothesis is absent
(see ``tests/_hypothesis_compat.py``).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (METRICS, bucket_n, corr_sh_medoid,
                        corr_sh_medoid_ragged, exact_medoid, get_backend,
                        list_backends, num_buckets_for_range, pack_queries,
                        pairwise, plan_buckets, round_schedule, schedule_pulls)

pytestmark = pytest.mark.ragged

# exact fp32 backends only: the quantized backends (repro.quant)
# are perturbed estimators by design — their parity/determinism
# contracts live in tests/test_quant.py and the quant section of
# tests/test_backends.py, at quantization-error tolerances
BACKENDS = [b for b in list_backends() if not b.startswith("quant_")]


# ------------------------- round_schedule properties ------------------------

@given(n=st.integers(2, 5000), per_arm=st.integers(1, 200))
@settings(max_examples=200, deadline=None)
def test_schedule_pull_ceiling(n, per_arm):
    """Pulls never exceed budget + n * ceil(log2 n): the t_r >= 1 floor costs
    at most s_r extra pulls per round, summed over <= ceil(log2 n) rounds."""
    budget = per_arm * n
    log2n = max(1, math.ceil(math.log2(n)))
    assert schedule_pulls(n, budget) <= budget + n * log2n


@given(n=st.integers(2, 5000), per_arm=st.integers(1, 200))
@settings(max_examples=100, deadline=None)
def test_schedule_survivors_halve_to_one(n, per_arm):
    rounds = round_schedule(n, per_arm * n)
    assert rounds[0].survivors == n
    for a, b in zip(rounds, rounds[1:]):
        assert b.survivors == math.ceil(a.survivors / 2)
    # termination: either an exact round, or the halving chain reached the
    # point where one more halving leaves a single survivor
    last = rounds[-1]
    assert last.exact or math.ceil(last.survivors / 2) == 1


@given(n=st.integers(2, 5000), per_arm=st.integers(1, 400))
@settings(max_examples=100, deadline=None)
def test_schedule_exact_flag_iff_refs_cover_n(n, per_arm):
    rounds = round_schedule(n, per_arm * n)
    for r in rounds:
        assert r.exact == (r.num_refs >= n)
    # an exact round ends the schedule immediately
    for r in rounds[:-1]:
        assert not r.exact


@given(n=st.integers(2, 2000), per_arm=st.integers(1, 100),
       extra=st.integers(0, 5000))
@settings(max_examples=100, deadline=None)
def test_schedule_monotone_in_budget(n, per_arm, extra):
    """More budget never shrinks a round's reference draw, and never adds
    rounds (exactness can only trigger earlier)."""
    lo = round_schedule(n, per_arm * n)
    hi = round_schedule(n, per_arm * n + extra)
    assert len(hi) <= len(lo)
    for a, b in zip(lo, hi):
        assert a.survivors == b.survivors
        assert b.num_refs >= a.num_refs


# -------------------------------- bucketing ---------------------------------

@given(n=st.integers(1, 100000))
@settings(max_examples=100, deadline=None)
def test_bucket_n_properties(n):
    b = bucket_n(n)
    assert b >= n and b >= 8
    assert b & (b - 1) == 0                       # power of two
    assert bucket_n(b) == b                        # idempotent on buckets
    if b > 8:
        assert b < 2 * n                           # never more than 2x waste


def test_plan_buckets_groups_and_order():
    plan = plan_buckets([3, 100, 64, 7, 257, 65])
    assert plan == {8: [0, 3], 128: [1, 5], 64: [2], 512: [4]}
    assert list(plan) == [8, 128, 64, 512]         # first-arrival order


def test_num_buckets_for_range():
    assert num_buckets_for_range(64, 64) == 1
    assert num_buckets_for_range(64, 1024) == 5    # 64,128,256,512,1024
    assert num_buckets_for_range(1, 8) == 1        # floor bucket


def test_pack_queries_shapes_and_validation():
    qs = [jnp.ones((3, 4)), jnp.ones((17, 4))]
    data, lengths = pack_queries(qs)
    assert data.shape == (2, 32, 4)
    assert lengths.tolist() == [3, 17]
    data, lengths = pack_queries(qs, pad_batch_to=4)
    assert data.shape == (4, 32, 4)
    assert lengths.tolist() == [3, 17, 1, 1]
    with pytest.raises(ValueError, match="at least one"):
        pack_queries([])
    with pytest.raises(ValueError, match="must be"):
        pack_queries([jnp.ones((3, 4)), jnp.ones((3, 5))])


# ------------------------ masked centrality primitive -----------------------

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_centrality_ref_mask_parity(backend, metric):
    """Every backend's centrality with a validity mask == the masked row sum
    of the reference pairwise block (invalid references contribute zero)."""
    k = jax.random.key(3)
    x = jax.random.normal(jax.random.fold_in(k, 1), (37, 12))
    y = jax.random.normal(jax.random.fold_in(k, 2), (23, 12))
    mask = (jax.random.uniform(jax.random.fold_in(k, 3), (23,)) < 0.6)
    got = get_backend(backend).centrality_sums(metric)(x, y, ref_mask=mask)
    want = jnp.sum(pairwise(metric)(x, y) * mask[None, :], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=5e-3 * 23)


# ------------------------------ engine parity -------------------------------

def _queries(ns, d, seed=0):
    k = jax.random.key(seed)
    return [jax.random.normal(jax.random.fold_in(k, i), (n, d))
            for i, n in enumerate(ns)]


@pytest.mark.parametrize("backend", BACKENDS)
def test_full_bucket_parity_is_bitexact(backend):
    """lengths == n_bucket: the masked engine IS the dense engine — same
    schedule, same reference permutations, same arithmetic, same medoids,
    in the *halving* regime (no exact-round crutch)."""
    b, n, d = 3, 64, 12
    data = jax.random.normal(jax.random.key(6), (b, n, d))
    key = jax.random.key(8)
    got = corr_sh_medoid_ragged(data, [n] * b, key, budget=n * 20,
                                backend=backend)
    keys = jax.random.split(key, b)
    want = [int(corr_sh_medoid(data[i], keys[i], budget=n * 20,
                               backend=backend)) for i in range(b)]
    assert [int(m) for m in got] == want


@pytest.mark.parametrize("backend", BACKENDS)
def test_mixed_n_parity_vs_per_query_loop(backend):
    """The acceptance batch: n in {64, 257, 1024} through one bucketed
    dispatch equals the per-query loop for every backend (exact-regime
    budget: both sides provably return the true medoid)."""
    ns = (64, 257, 1024)
    qs = _queries(ns, d=6, seed=1)
    data, lengths = pack_queries(qs)
    assert data.shape[1] == 1024
    budget = 1024 * 10 * 1024          # t_0 == n_bucket: exact first round
    key = jax.random.key(5)
    got = corr_sh_medoid_ragged(data, lengths, key, budget=budget,
                                backend=backend)
    keys = jax.random.split(key, len(qs))
    singles = [int(corr_sh_medoid(qs[i], keys[i], budget=budget,
                                  backend=backend)) for i in range(len(qs))]
    exact = [int(exact_medoid(q, "l2")) for q in qs]
    assert [int(m) for m in got] == singles == exact


@pytest.mark.parametrize("metric", ["l1", "cosine"])
def test_mixed_n_parity_other_metrics(metric):
    qs = _queries((5, 33, 64), d=8, seed=2)
    data, lengths = pack_queries(qs)
    budget = 64 * 7 * 64
    key = jax.random.key(9)
    got = corr_sh_medoid_ragged(data, lengths, key, budget=budget,
                                metric=metric, backend="pallas_fused")
    assert [int(m) for m in got] == [int(exact_medoid(q, metric)) for q in qs]


@pytest.mark.parametrize("backend", BACKENDS)
def test_edge_queries_n1_n2(backend):
    """n=1 and n=2 queries ride the same bucket as bigger neighbors."""
    qs = _queries((1, 2, 5), d=4, seed=3)
    data, lengths = pack_queries(qs)
    assert data.shape[1] == 8                      # floor bucket
    key = jax.random.key(4)
    got = corr_sh_medoid_ragged(data, lengths, key, budget=8 * 3 * 8,
                                backend=backend)
    keys = jax.random.split(key, 3)
    singles = [int(corr_sh_medoid(qs[i], keys[i], budget=8 * 3 * 8,
                                  backend=backend)) for i in range(3)]
    assert [int(m) for m in got] == singles
    for m, n in zip(got, (1, 2, 5)):
        assert 0 <= int(m) < n                     # never a padded arm


def test_all_padding_rejected():
    data = jnp.zeros((3, 8, 4))
    with pytest.raises(ValueError, match="all-padding"):
        corr_sh_medoid_ragged(data, [2, 0, 5], jax.random.key(0), budget=100)
    with pytest.raises(ValueError, match="exceeds"):
        corr_sh_medoid_ragged(data, [2, 9, 5], jax.random.key(0), budget=100)
    with pytest.raises(ValueError, match="expected"):
        corr_sh_medoid_ragged(jnp.zeros((8, 4)), [8], jax.random.key(0),
                              budget=100)
    with pytest.raises(ValueError, match="lengths"):
        corr_sh_medoid_ragged(data, [2, 5], jax.random.key(0), budget=100)


def test_raw_nmax_never_reaches_the_jit_cache():
    """Two raw paddings in the same bucket share one compiled program: the
    wrapper bucket-pads BEFORE the jit boundary, so the compile cap holds
    for callers that don't pre-pad (regression for padding inside the jit)."""
    from repro.core import ragged_compile_count

    key = jax.random.key(0)
    qs = _queries((70, 90), d=4, seed=8)   # both bucket to 128
    c0 = ragged_compile_count()
    a = corr_sh_medoid_ragged(qs[0][None], [70], key, budget=128 * 8)
    b = corr_sh_medoid_ragged(qs[1][None], [90], key, budget=128 * 8)
    assert ragged_compile_count() - c0 <= 1
    assert 0 <= int(a[0]) < 70 and 0 <= int(b[0]) < 90


def test_ragged_deterministic_same_key():
    qs = _queries((9, 33, 64, 2), d=8, seed=7)
    data, lengths = pack_queries(qs)
    a = corr_sh_medoid_ragged(data, lengths, jax.random.key(11), budget=64 * 12)
    b = corr_sh_medoid_ragged(data, lengths, jax.random.key(11), budget=64 * 12)
    assert [int(x) for x in a] == [int(x) for x in b]
