"""`repro.api` facade: configs, routing, deprecation shims, single-copy guard.

The facade is the only documented entry surface after PR 4; these tests pin

* config resolution (dataclass + keyword overrides, bad keys fail loudly),
* algorithm routing (``corr_sh`` | ``meddit`` | ``rand`` | ``exact``),
* the deprecated pre-facade names still working and warning EXACTLY once
  per process each,
* facade results matching the shims bit-for-bit (they share one engine), and
* the single-copy guard: no ``_run_rounds``-style halving skeleton may exist
  under ``src/`` outside ``src/repro/engine/`` (mirrored by a grep step in
  CI; the verbatim legacy copies live in ``tests/_legacy_loops.py``).
"""
import re
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp

import pytest

from repro import deprecation
from repro.api import (ALGOS, KMedoidsConfig, MedoidConfig, MedoidResult,
                       find_medoid, find_medoids_batch, find_medoids_ragged,
                       kmedoids)
from repro.core import exact_medoid, pack_queries

pytestmark = pytest.mark.engine


# ------------------------------ configs/routing -----------------------------

def test_config_overrides_equivalent_to_dataclass():
    data = jax.random.normal(jax.random.key(0), (96, 8))
    key = jax.random.key(1)
    a = find_medoid(data, key, config=MedoidConfig(metric="l1",
                                                   budget_per_arm=12))
    b = find_medoid(data, key, metric="l1", budget_per_arm=12)
    assert a == b
    assert isinstance(a, MedoidResult) and a.n == 96 and a.algo == "corr_sh"
    assert a.pulls == sum(s * t for s, t in a.rounds)


def test_bad_override_and_algo_fail_loudly():
    data = jnp.zeros((8, 2))
    with pytest.raises(TypeError):
        find_medoid(data, jax.random.key(0), no_such_knob=1)
    with pytest.raises(ValueError, match="unknown algo"):
        find_medoid(data, jax.random.key(0), algo="quantum")
    with pytest.raises(ValueError, match="expected"):
        find_medoid(jnp.zeros((8,)), jax.random.key(0))
    with pytest.raises(ValueError, match="algo='corr_sh'"):
        find_medoids_batch(jnp.zeros((2, 8, 2)), jax.random.key(0),
                           algo="exact")
    with pytest.raises(TypeError, match="config must be"):
        find_medoid(data, jax.random.key(0), config=KMedoidsConfig())


def test_exact_and_rand_and_meddit_routes():
    data = jax.random.normal(jax.random.key(2), (64, 8))
    key = jax.random.key(3)
    truth = int(exact_medoid(data, "l2"))
    ex = find_medoid(data, key, algo="exact")
    assert ex.medoid == truth and ex.pulls == 64 * 64
    rd = find_medoid(data, key, algo="rand", budget_per_arm=32)
    assert 0 <= rd.medoid < 64 and rd.pulls == 64 * 32
    md = find_medoid(data, key, algo="meddit")
    assert 0 <= md.medoid < 64 and md.pulls > 0


def test_exact_regime_budget_recovers_truth():
    data = jax.random.normal(jax.random.key(4), (128, 8))
    res = find_medoid(data, jax.random.key(5), budget_per_arm=128 * 7)
    assert res.medoid == int(exact_medoid(data, "l2"))
    assert len(res.rounds) == 1            # one exact round, output now


def test_n1_and_default_key():
    res = find_medoid(jnp.zeros((1, 4)))
    assert res == MedoidResult(medoid=0, pulls=0, n=1, algo="corr_sh",
                               metric="l2", backend="reference")
    assert find_medoid(jnp.zeros((1, 4)), config=MedoidConfig(seed=7)).medoid == 0


def test_ragged_accepts_list_and_packed():
    qs = [jax.random.normal(jax.random.fold_in(jax.random.key(6), i), (n, 4))
          for i, n in enumerate((5, 33, 64))]
    key = jax.random.key(7)
    a = find_medoids_ragged(qs, key=key, budget_per_arm=12)
    data, lengths = pack_queries(qs)
    b = find_medoids_ragged(data, lengths, key, budget_per_arm=12)
    assert [int(m) for m in a] == [int(m) for m in b]
    for m, q in zip(a, qs):
        assert 0 <= int(m) < q.shape[0]
    with pytest.raises(ValueError, match="lengths"):
        find_medoids_ragged(data, key=key)          # packed without lengths
    with pytest.raises(ValueError, match="lengths only"):
        find_medoids_ragged(qs, [5, 33, 64], key)   # both styles at once


def test_kmedoids_facade_runs_and_accounts():
    from repro.data.medoid_datasets import planted_clusters

    data, labels = planted_clusters(jax.random.key(8), 200, d=8, k=3)
    res = kmedoids(data, 3, jax.random.key(9),
                   config=KMedoidsConfig(refine_sweeps=1))
    assert len(res.medoids) == 3
    assert res.pulls == (res.build_pulls + res.assign_pulls
                         + res.refine_pulls + res.swap_pulls)


# ------------------------------- deprecation --------------------------------

def test_deprecated_entrypoints_warn():
    """Every pre-facade entry point still works, returns exactly what the
    facade returns, and warns exactly ONCE per process no matter how many
    times it is called."""
    from repro.cluster import bandit_kmedoids
    from repro.core import (corr_sh_medoid, corr_sh_medoid_batch,
                            corr_sh_medoid_ragged)
    from repro.data.medoid_datasets import planted_clusters

    deprecation._reset_for_tests()
    data = jax.random.normal(jax.random.key(10), (64, 8))
    key = jax.random.key(11)
    batch = jax.random.normal(jax.random.key(12), (2, 32, 4))
    qs = [jax.random.normal(jax.random.fold_in(jax.random.key(13), i), (n, 4))
          for i, n in enumerate((5, 17))]
    packed, lengths = pack_queries(qs)
    cdata, _ = planted_clusters(jax.random.key(14), 96, d=4, k=2)

    calls = {
        "corr_sh_medoid": lambda: int(corr_sh_medoid(data, key,
                                                     budget=16 * 64)),
        "corr_sh_medoid_batch": lambda: [int(m) for m in corr_sh_medoid_batch(
            batch, key, budget=16 * 32)],
        "corr_sh_medoid_ragged": lambda: [int(m) for m in
                                          corr_sh_medoid_ragged(
                                              packed, lengths, key,
                                              budget=16 * 32)],
        "bandit_kmedoids": lambda: bandit_kmedoids(
            cdata, 2, key, refine_sweeps=0, max_swap_rounds=0).medoids,
    }
    results = {}
    for name, call in calls.items():
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            results[name] = call()
            call()                                   # second call: no warning
        dep = [w for w in caught if issubclass(w.category, DeprecationWarning)
               and "repro.api" in str(w.message)]
        assert len(dep) == 1, (name, [str(w.message) for w in caught])

    # shims delegate to the same engine the facade uses: identical answers
    assert results["corr_sh_medoid"] == find_medoid(
        data, key, budget_per_arm=16).medoid
    assert results["corr_sh_medoid_batch"] == [int(m) for m in
                                               find_medoids_batch(
                                                   batch, key,
                                                   budget_per_arm=16)]
    assert results["corr_sh_medoid_ragged"] == [int(m) for m in
                                                find_medoids_ragged(
                                                    packed, lengths, key,
                                                    budget_per_arm=16)]
    assert results["bandit_kmedoids"] == kmedoids(
        cdata, 2, key, refine_sweeps=0, max_swap_rounds=0).medoids


# ----------------------------- single-copy guard ----------------------------

# the fingerprint of the duplicated skeleton: the halving step's
# ceil-half-survivors computation over a live index array (and the
# historical `while len(survivors)` form). Estimators/backends never need
# it; only the engine halves. (The distributed shard_map loops halve static
# Python ints — a documented, pre-existing specialization kept out of this
# fingerprint on purpose.)
_GUARD = re.compile(
    r"ceil\(\s*\w+\.shape\[0\]\s*/\s*2\s*\)|while\s+len\(survivors\)")


def test_no_round_loop_copies_outside_engine():
    src = Path(__file__).resolve().parent.parent / "src"
    offenders = []
    for p in sorted(src.rglob("*.py")):
        rel = p.relative_to(src).as_posix()
        if rel.startswith("repro/engine/"):
            continue
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if _GUARD.search(line):
                offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, (
        "halving-skeleton copy outside src/repro/engine/ — plug an "
        "ArmEstimator into repro.engine.run_halving instead:\n"
        + "\n".join(offenders))
