"""Service-level tests for the continuous-batching medoid server.

Invariants under a synthetic mixed-size trace:

* liveness/uniqueness — every submitted request is answered exactly once,
  with a medoid index inside its own query (never a padded arm or a dummy
  batch slot);
* compile discipline — the ragged engine traces at most one XLA program per
  distinct (n_bucket, d) the trace touches, because every dispatch of a
  bucket has the identical static signature (fixed max_batch slots,
  bucket-derived budget);
* admission — empty queries and duplicate request ids are rejected at
  submit(), never mid-dispatch.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import exact_medoid
from repro.core.bucketing import bucket_n
from repro.launch.serve_medoid import MedoidServer, synthetic_trace

pytestmark = pytest.mark.ragged


def _trace(ns, d=8, seed=0):
    k = jax.random.key(seed)
    return [jax.random.normal(jax.random.fold_in(k, i), (n, d))
            for i, n in enumerate(ns)]


def test_every_request_answered_exactly_once_and_compiles_bounded():
    ns = [3, 100, 64, 7, 257, 65, 64, 12, 300, 1, 80, 33, 2]
    queries = _trace(ns)
    srv = MedoidServer(budget_per_arm=8, max_batch=4)
    rids = []
    # staggered arrivals: a few requests admitted between scheduler steps
    it = iter(queries)
    admitted = 0
    while admitted < len(queries) or srv.pending:
        for _ in range(3):
            q = next(it, None)
            if q is not None:
                rids.append(srv.submit(q))
                admitted += 1
        answered = srv.step()
        for req in answered:
            assert req.done and 0 <= req.medoid < req.n

    assert sorted(srv.done) == sorted(rids) and len(rids) == len(ns)
    assert len(set(rids)) == len(rids)
    # dummy padding slots never surface as answers
    assert len(srv.done) == len(ns)
    # one compiled program per distinct bucket, at most
    distinct_buckets = {bucket_n(n) for n in ns}
    assert srv.stats()["distinct_buckets"] == len(distinct_buckets)
    assert srv.recompiles <= len(distinct_buckets)


def test_answers_match_exact_medoid_with_generous_budget():
    ns = [5, 17, 30, 9, 64]
    queries = _trace(ns, d=6, seed=4)
    # budget_per_arm >= n_bucket * ceil(log2 n_bucket): first round exact
    srv = MedoidServer(budget_per_arm=64 * 6, max_batch=3)
    rids = [srv.submit(q) for q in queries]
    srv.drain()
    for rid, q in zip(rids, queries):
        assert srv.done[rid].medoid == int(exact_medoid(q, "l2"))


def test_fifo_within_bucket_and_batched_dispatch():
    # 5 same-bucket queries, max_batch=2 -> 3 dispatches, oldest first
    queries = _trace([30, 20, 25, 31, 17], seed=2)
    srv = MedoidServer(budget_per_arm=8, max_batch=2)
    rids = [srv.submit(q) for q in queries]
    first = srv.step()
    assert [r.rid for r in first] == rids[:2]
    srv.drain()
    assert srv.dispatches == 3
    assert srv.stats()["distinct_buckets"] == 1


def test_admission_rejections():
    # misconfiguration fails at construction, never mid-dispatch (a dispatch
    # failure would otherwise have to re-queue the batch)
    with pytest.raises(ValueError, match="unknown backend"):
        MedoidServer(backend="pallas_fuse")
    with pytest.raises(ValueError, match="unknown metric"):
        MedoidServer(metric="euclid")
    srv = MedoidServer()
    with pytest.raises(ValueError, match="all-padding"):
        srv.submit(jnp.zeros((0, 4)))
    with pytest.raises(ValueError, match="\\(n, d\\)"):
        srv.submit(jnp.zeros((4,)))
    rid = srv.submit(jnp.zeros((3, 4)))
    with pytest.raises(ValueError, match="duplicate"):
        srv.submit(jnp.zeros((5, 4)), rid=rid)


def test_request_accounting():
    srv = MedoidServer(budget_per_arm=8, max_batch=2)
    srv.submit(_trace([12], seed=5)[0])
    srv.submit(_trace([40], seed=6)[0])   # different bucket: waits one step
    srv.step()
    assert srv.pending == 1
    srv.step()
    assert srv.pending == 0
    reqs = sorted(srv.done.values(), key=lambda r: r.rid)
    assert reqs[0].wait_steps == 0 and reqs[1].wait_steps == 1
    assert all(r.pulls > 0 and r.batch_wall_s >= 0 for r in reqs)


def test_synthetic_trace_shapes():
    tr = synthetic_trace(6, 4, 100, 8, seed=1)
    assert len(tr) == 6
    assert all(t.ndim == 2 and 4 <= t.shape[0] <= 100 and t.shape[1] == 8
               for t in tr)
