"""Frozen PRE-REFACTOR copies of the four round-loop bodies (PR-3 state).

Before PR 4, the correlated-SH round skeleton (draw shared references ->
score all survivors -> halve via top-k) existed four times: ``_run_rounds``
and ``_run_rounds_masked`` in ``repro/core/corr_sh.py``, ``_build_step`` and
``_swap_argmin`` in ``repro/cluster/kmedoids.py``. PR 4 consolidates them
behind the estimator-parameterized ``repro.engine.run_halving``.

This module is the bit-exactness oracle for that consolidation: verbatim
snapshots of the old loops (plus the helpers they closed over), frozen at
commit e63c8bc. ``tests/test_engine.py`` runs old-vs-new under fixed keys and
asserts identical winners, identical pull accounting, and bit-identical
estimates for every registered backend.

Deliberately duplicated HERE, under ``tests/`` — the single-copy grep guard
(``tests/test_api.py::test_no_round_loop_copies_outside_engine`` and the CI
step) forbids this skeleton under ``src/`` outside ``src/repro/engine/``.
"""
from __future__ import annotations

import functools
import inspect
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import distances
from repro.core.backend import get_backend
from repro.engine import round_schedule


# --------------------------- legacy loop helpers ----------------------------
# (verbatim from pre-refactor repro/core/corr_sh.py)

def _sample_refs(key: jax.Array, n: int, t: int) -> jnp.ndarray:
    if t >= n:
        return jnp.arange(n, dtype=jnp.int32)
    return jax.random.permutation(key, n)[:t].astype(jnp.int32)


def _sample_refs_masked(key: jax.Array, n: int, t: int,
                        valid: jnp.ndarray) -> jnp.ndarray:
    if t >= n:
        return jnp.arange(n, dtype=jnp.int32)
    perm = jax.random.permutation(key, n).astype(jnp.int32)
    order = jnp.argsort(jnp.where(valid[perm], 0, 1))  # jnp sort is stable
    return perm[order][:t]


def _default_select(theta: jnp.ndarray, keep: int) -> jnp.ndarray:
    return jax.lax.top_k(-theta, keep)[1]


def _resolve_select_fn(backend) -> Callable:
    fn = get_backend(backend).survivor_topk
    return fn if fn is not None else _default_select


def _resolve_theta_fn(metric: str, pairwise_fn, backend) -> Callable:
    if pairwise_fn is not None:
        return lambda x, y: jnp.sum(pairwise_fn(x, y), axis=1)
    return get_backend(backend).centrality_sums(metric)


def _resolve_masked_theta_fn(metric: str, backend) -> Callable:
    be = get_backend(backend)
    fn = be.centrality_sums(metric)
    try:
        params = inspect.signature(fn).parameters
        mask_native = "ref_mask" in params or any(
            p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())
    except (TypeError, ValueError):
        mask_native = False
    if mask_native:
        return lambda x, y, m: fn(x, y, ref_mask=m)
    pw = be.pairwise(metric)
    return lambda x, y, m: distances.masked_rowsum(pw(x, y), m)


# ------------------------ legacy loop 1: _run_rounds ------------------------

def _run_rounds(data: jnp.ndarray, key: jax.Array, rounds, n: int,
                theta_fn: Callable, select_fn: Callable = _default_select):
    idx = jnp.arange(n, dtype=jnp.int32)
    theta_hat = None
    for r, rd in enumerate(rounds):
        key, sub = jax.random.split(key)
        refs = _sample_refs(sub, n, rd.num_refs)
        cand_rows = data[idx]
        ref_rows = data[refs]
        theta_hat = theta_fn(cand_rows, ref_rows) / ref_rows.shape[0]
        if rd.exact or idx.shape[0] <= 2:
            return idx[jnp.argmin(theta_hat)], theta_hat, r
        keep = math.ceil(idx.shape[0] / 2)
        idx = idx[select_fn(theta_hat, keep)]
    return idx[jnp.argmin(theta_hat)], theta_hat, len(rounds) - 1


# --------------------- legacy loop 2: _run_rounds_masked --------------------

def _run_rounds_masked(data: jnp.ndarray, valid: jnp.ndarray, key: jax.Array,
                       rounds, n: int, theta_fn: Callable,
                       select_fn: Callable = _default_select):
    idx = jnp.arange(n, dtype=jnp.int32)
    theta_hat = None
    for r, rd in enumerate(rounds):
        key, sub = jax.random.split(key)
        refs = _sample_refs_masked(sub, n, rd.num_refs, valid)
        ref_mask = valid[refs].astype(jnp.float32)
        sums = theta_fn(data[idx], data[refs], ref_mask)
        denom = jnp.maximum(jnp.sum(ref_mask), 1.0)
        theta_hat = jnp.where(valid[idx], sums / denom, jnp.inf)
        if rd.exact or idx.shape[0] <= 2:
            return idx[jnp.argmin(theta_hat)], theta_hat, r
        keep = math.ceil(idx.shape[0] / 2)
        idx = idx[select_fn(theta_hat, keep)]
    return idx[jnp.argmin(theta_hat)], theta_hat, len(rounds) - 1


# ----------------------- legacy jitted single/batch entry -------------------

def legacy_correlated_sequential_halving(data, budget, key, metric="l2",
                                         backend="reference"):
    """Pre-refactor ``correlated_sequential_halving`` (result tuple only)."""
    n = int(data.shape[0])
    rounds = round_schedule(n, budget)
    theta_fn = _resolve_theta_fn(metric, None, backend)
    select_fn = _resolve_select_fn(backend)
    medoid, theta_hat, r_stop = _run_rounds(data, key, rounds, n, theta_fn,
                                            select_fn)
    pulls = sum(x.pulls for x in rounds[: r_stop + 1])
    return medoid, theta_hat, pulls


@functools.partial(jax.jit, static_argnames=("budget", "metric", "backend"))
def legacy_corr_sh_medoid(data, key, *, budget: int, metric: str = "l2",
                          backend: str = "reference"):
    return legacy_correlated_sequential_halving(data, budget, key, metric,
                                                backend)[0]


@functools.partial(jax.jit, static_argnames=("budget", "metric", "backend"))
def legacy_corr_sh_medoid_batch(data, key, *, budget: int, metric: str = "l2",
                                backend: str = "reference"):
    b, n, _ = data.shape
    rounds = round_schedule(n, budget)
    keys = jax.random.split(key, b)
    if not rounds:
        return jnp.zeros((b,), jnp.int32)
    theta_fn = _resolve_theta_fn(metric, None, backend)
    select_fn = _resolve_select_fn(backend)

    def one(x, k):
        return _run_rounds(x, k, rounds, n, theta_fn, select_fn)[0]

    return jax.vmap(one)(data, keys)


@functools.partial(jax.jit,
                   static_argnames=("budget", "metric", "backend", "n_bucket"))
def legacy_ragged_impl(data, lengths, key, *, budget: int, metric: str,
                       backend: str, n_bucket: int):
    """Pre-refactor ``_ragged_impl`` (callers must pre-pad to ``n_bucket``)."""
    b = data.shape[0]
    rounds = round_schedule(n_bucket, budget)
    if not rounds:
        return jnp.zeros((b,), jnp.int32)
    valid = jnp.arange(n_bucket, dtype=jnp.int32)[None, :] < lengths[:, None]
    keys = jax.random.split(key, b)
    theta_fn = _resolve_masked_theta_fn(metric, backend)
    select_fn = _resolve_select_fn(backend)

    def one(x, v, k):
        return _run_rounds_masked(x, v, k, rounds, n_bucket, theta_fn,
                                  select_fn)[0]

    return jax.vmap(one)(data, valid, keys)


# ------------------------ legacy loop 3: _build_step ------------------------

@functools.partial(jax.jit, static_argnames=("budget", "metric", "backend"))
def legacy_build_step(data, d1, chosen, key, *, budget: int, metric: str,
                      backend: str):
    n = data.shape[0]
    rounds = round_schedule(n, budget)
    pw = get_backend(backend).pairwise(metric)
    select_fn = _resolve_select_fn(backend)
    idx = jnp.arange(n, dtype=jnp.int32)
    arm_ok = ~chosen
    theta = None
    for rd in rounds:
        key, sub = jax.random.split(key)
        refs = _sample_refs(sub, n, rd.num_refs)
        blk = pw(data[idx], data[refs])
        sums = jnp.sum(jnp.minimum(blk, d1[refs][None, :]), axis=1)
        theta = jnp.where(arm_ok[idx], sums / refs.shape[0], jnp.inf)
        if rd.exact or idx.shape[0] <= 2:
            return idx[jnp.argmin(theta)]
        keep = math.ceil(idx.shape[0] / 2)
        idx = idx[select_fn(theta, keep)]
    return idx[jnp.argmin(theta)]


# ----------------------- legacy loop 4: _swap_argmin ------------------------

@functools.partial(jax.jit,
                   static_argnames=("budget", "k", "metric", "backend"))
def legacy_swap_argmin(data, d1, d2, nearest, chosen, key, *, budget: int,
                       k: int, metric: str, backend: str):
    n = data.shape[0]
    rounds = round_schedule(n, budget)
    pw = get_backend(backend).pairwise(metric)
    select_fn = _resolve_select_fn(backend)
    idx = jnp.arange(n, dtype=jnp.int32)
    arm_ok = ~chosen
    theta = delta = None
    for rd in rounds:
        key, sub = jax.random.split(key)
        refs = _sample_refs(sub, n, rd.num_refs)
        blk = pw(data[idx], data[refs])
        d1r, d2r = d1[refs][None, :], d2[refs][None, :]
        gain = jnp.minimum(blk - d1r, 0.0)
        term = jnp.minimum(blk, d2r) - d1r - gain
        onehot = jax.nn.one_hot(nearest[refs], k, dtype=blk.dtype)
        delta = jnp.sum(gain, axis=1, keepdims=True) + term @ onehot
        best = jnp.min(delta, axis=1)
        theta = jnp.where(arm_ok[idx], best / refs.shape[0], jnp.inf)
        if rd.exact or idx.shape[0] <= 2:
            break
        keep = math.ceil(idx.shape[0] / 2)
        idx = idx[select_fn(theta, keep)]
    c_pos = jnp.argmin(theta)
    slot = jnp.argmin(delta[c_pos]).astype(jnp.int32)
    return idx[c_pos], slot, theta[c_pos]
