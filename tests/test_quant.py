"""Quantized distance subsystem (PR 10): the soundness + exactness contracts.

What the subsystem promises, and what this suite pins:

* **certified error model** — the analytic per-distance bound of
  :func:`repro.quant.analytic_distance_bound` actually dominates the
  observed ``max |d_q - d_f|`` on full pairwise blocks, for every metric
  and both quantized precisions;
* **soundness of the widened halving** (the hypothesis property of the
  issue): on adversarial near-tie instances, whenever the capacity
  certificate ``margin_ok`` holds, the margin-widened quantized run NEVER
  drops the arm the same-draw fp32 run selects — it is always among the
  live finalists the exact epilogue scores;
* **exactness of the served answer** — the quantized facade's medoid is
  never worse (in exact fp32 centrality) than the fp32 facade's answer for
  the same key: verified runs return the exact-centrality argmin of a
  finalist superset, unverified runs fall back to the same-key fp32 run;
* **plumbing parity** — batch/ragged quantized dispatches match the
  single-query quantized facade under the engine's key-splitting contract;
  pulls account for the verification epilogue; the quantized
  ``CorpusStore`` / ``maintain_medoid`` / k-medoids / ``MedoidServer``
  paths run the quantized backends end to end (with warmup pre-tracing
  every variant a live dispatch can select).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import quant
from repro.api import (MedoidConfig, find_medoid, find_medoids_batch,
                       find_medoids_ragged, maintain_medoid)
from repro.core import METRICS, exact_medoid, pairwise
from repro.engine import (HalvingProblem, medoid_centrality, round_schedule,
                          run_halving)

pytestmark = pytest.mark.quant

QUANT = ("bf16", "int8")


def _near_tie_data(seed: int, n_base: int = 24, d: int = 6,
                   jitter: float = 1e-3):
    """Adversarial near-ties: every point has a twin ``jitter`` away, so
    survivor cuts land inside clusters of nearly-equal centralities — the
    regime where an unwidened quantized run evicts fp32 survivors."""
    key = jax.random.key(seed)
    base = jax.random.normal(jax.random.fold_in(key, 0), (n_base, d))
    pts = jnp.concatenate([base, base], axis=0)
    noise = jitter * jax.random.normal(jax.random.fold_in(key, 1),
                                       pts.shape)
    return pts + noise, jax.random.fold_in(key, 2)


# ------------------------------ error model ---------------------------------

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("precision", QUANT)
def test_analytic_bound_dominates_observed_error(metric, precision):
    data = jax.random.normal(jax.random.key(17), (96, 12)) * 1.7
    dq = quant.quant_pairwise(metric, precision)(data, data)
    df = pairwise(metric)(data, data)
    observed = float(jnp.max(jnp.abs(dq - df)))
    bound = float(quant.analytic_distance_bound(data, metric, precision))
    assert observed <= bound * (1.0 + 1e-5), (metric, precision,
                                              observed, bound)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("precision", QUANT)
def test_probe_margin_positive_and_below_analytic(metric, precision):
    """The probe statistic measures mean-over-refs perturbation, so (at the
    shared safety factor) it must sit at or below the certified worst-case
    — that gap is exactly why the probe model's margins are usable."""
    data = jax.random.normal(jax.random.key(23), (200, 10))
    probe = float(quant.margin(data, metric, precision, model="probe"))
    analytic = float(quant.margin(data, metric, precision,
                                  model="analytic"))
    assert 0.0 < probe
    assert probe <= quant.DEFAULT_SAFETY * analytic


def test_margin_fp32_is_zero_and_model_validated():
    data = jnp.ones((8, 3))
    assert float(quant.margin(data, "l2", "fp32")) == 0.0
    with pytest.raises(ValueError, match="unknown error model"):
        quant.margin(data, "l2", "bf16", model="exact")
    with pytest.raises(ValueError, match="unknown precision"):
        quant.check_precision("fp16")


# --------------------- widened halving: soundness property -------------------

@given(seed=st.integers(0, 300), precision=st.sampled_from(QUANT))
@settings(max_examples=20, deadline=None)
def test_widened_halving_never_drops_fp32_winner_on_near_ties(seed,
                                                              precision):
    """THE soundness property: with the analytic (certified) margin, a
    margin-widened quantized run whose capacity certificate holds retains
    the arm the same-draw fp32 run selects among its live finalists."""
    data, key = _near_tie_data(seed)
    n = int(data.shape[0])
    rounds = round_schedule(n, 16 * n)
    backend = quant.backend_for(precision)
    widen = quant.margin(data, "l2", precision, model="analytic")
    out_q = run_halving(
        HalvingProblem(data, medoid_centrality(backend, "l2")),
        rounds, backend, key=key, widen=widen)
    out_f = run_halving(
        HalvingProblem(data, medoid_centrality("reference", "l2")),
        rounds, "reference", key=key)
    if bool(out_q.margin_ok):
        finalists = np.asarray(out_q.survivors)[: int(out_q.live)]
        assert int(out_f.winner) in set(finalists.tolist()), (
            seed, precision, int(out_f.winner), finalists)


@given(seed=st.integers(0, 300), precision=st.sampled_from(QUANT))
@settings(max_examples=15, deadline=None)
def test_facade_answer_never_worse_than_fp32_on_near_ties(seed, precision):
    """End-to-end exactness: the quantized facade's answer has exact fp32
    centrality <= the fp32 facade's answer for the same key — verified runs
    return the exact argmin of a finalist superset; unverified runs ARE the
    same-key fp32 run."""
    data, key = _near_tie_data(seed)
    f = find_medoid(data, key, budget_per_arm=16)
    q = find_medoid(data, key, budget_per_arm=16, precision=precision,
                    quant_error_model="analytic")
    assert q.verified in (True, False)
    if q.verified is False:
        assert q.medoid == f.medoid          # same-key fp32 fallback
    cent = jnp.sum(pairwise("l2")(data, data), axis=1)
    assert float(cent[q.medoid]) <= float(cent[f.medoid]) * (1 + 1e-6)


def test_unwidened_runs_carry_no_certificate():
    data = jax.random.normal(jax.random.key(5), (64, 8))
    rounds = round_schedule(64, 16 * 64)
    out = run_halving(HalvingProblem(data, medoid_centrality()), rounds,
                      key=jax.random.key(1))
    assert out.live is None and out.margin_ok is None


# --------------------------- exact fp32 epilogue -----------------------------

def test_exact_winner_is_exact_argmin_of_live_finalists():
    data = jax.random.normal(jax.random.key(31), (80, 7))
    n = int(data.shape[0])
    rounds = round_schedule(n, 16 * n)
    widen = quant.margin(data, "l2", "int8", model="probe")
    problem = HalvingProblem(data, medoid_centrality("quant_int8", "l2"))
    out = run_halving(problem, rounds, "quant_int8",
                      key=jax.random.key(3), widen=widen)
    winner, verified = quant.exact_winner(problem, out, "l2")
    finalists = np.asarray(out.survivors)[: int(out.live)]
    cent = np.asarray(jnp.sum(pairwise("l2")(data, data), axis=1))
    assert int(winner) == int(finalists[np.argmin(cent[finalists])])
    assert bool(verified) == bool(out.margin_ok)
    assert quant.verify_pulls(n, rounds) == \
        quant.verify_width(n, rounds) * n


# ------------------------------ facade plumbing ------------------------------

def test_facade_validation():
    data = jnp.ones((8, 3))
    with pytest.raises(ValueError, match="unknown precision"):
        find_medoid(data, jax.random.key(0), precision="fp16")
    with pytest.raises(ValueError, match="requires algo='corr_sh'"):
        find_medoid(data, jax.random.key(0), precision="bf16", algo="exact")


@pytest.mark.parametrize("precision", QUANT)
def test_facade_pulls_account_for_verification(precision):
    n = 64
    data = jax.random.normal(jax.random.key(n), (n, 8))
    key = jax.random.key(1000 + n)
    f = find_medoid(data, key, budget_per_arm=16)
    q = find_medoid(data, key, budget_per_arm=16, precision=precision)
    rounds = round_schedule(n, 16 * n)
    assert q.precision == precision
    want = f.pulls + quant.verify_pulls(n, rounds)
    if q.verified:
        assert q.pulls == want
    else:
        assert q.pulls == want + f.pulls      # + the fp32 fallback re-run
    assert 0 <= q.medoid < n


@pytest.mark.parametrize("precision", QUANT)
def test_batch_matches_single_query_quantized(precision):
    b, n, d = 3, 64, 8
    data = jax.random.normal(jax.random.key(6), (b, n, d))
    key = jax.random.key(8)
    got = find_medoids_batch(data, key, budget_per_arm=16,
                             precision=precision)
    keys = jax.random.split(key, b)
    singles = [find_medoid(data[i], keys[i], budget_per_arm=16,
                           precision=precision).medoid for i in range(b)]
    assert [int(m) for m in got] == singles


@pytest.mark.parametrize("precision", QUANT)
def test_ragged_full_bucket_matches_single_query_quantized(precision):
    n, d = 64, 8
    qs = [jax.random.normal(jax.random.fold_in(jax.random.key(42), i),
                            (n, d)) for i in range(2)]
    key = jax.random.key(77)
    got = find_medoids_ragged(qs, key=key, budget_per_arm=16,
                              precision=precision)
    keys = jax.random.split(key, 2)
    singles = [find_medoid(qs[i], keys[i], budget_per_arm=16,
                           precision=precision).medoid for i in range(2)]
    assert [int(m) for m in got] == singles


def test_single_point_short_circuit():
    res = find_medoid(jnp.ones((1, 4)), jax.random.key(0), precision="int8")
    assert (res.medoid, res.pulls, res.verified) == (0, 0, True)


def test_telemetry_carries_hardness_and_certificate():
    data = jax.random.normal(jax.random.key(64), (64, 8))
    res = find_medoid(data, jax.random.key(1064), budget_per_arm=16,
                      precision="bf16", telemetry=True)
    assert res.verified in (True, False)
    assert res.telemetry is not None
    assert set(res.hardness) == {"delta2", "sigma", "h2", "h2_tilde"}
    assert res.hardness["delta2"] >= 0.0 and res.hardness["h2"] > 0.0


# ----------------------- downstream consumers (serving) ----------------------

def test_corpus_store_and_maintained_medoid_quantized():
    from repro.serve.corpus import CorpusStore

    data = np.asarray(jax.random.normal(jax.random.key(3), (60, 5)))
    store = CorpusStore.from_points(data, precision="int8",
                                    metric="l2")
    assert store.precision == "int8" and store.backend == "quant_int8"
    assert store.n == 60

    mm = maintain_medoid(data, config=MedoidConfig(precision="int8"))
    slot, version = mm.query()
    # quantized-exact incremental centralities on generic-position data:
    # the maintained winner is the exact fp32 medoid
    assert slot == int(exact_medoid(jnp.asarray(data), "l2"))
    mm.insert(np.zeros((5,), np.float32))
    slot2, version2 = mm.query()
    assert version2 > version and mm.store.is_live(slot2)


def test_kmedoids_runs_on_quant_backend():
    from repro.api import KMedoidsConfig, kmedoids

    data = jax.random.normal(jax.random.key(12), (96, 6))
    res = kmedoids(data, 4, jax.random.key(13),
                   config=KMedoidsConfig(backend="quant_bf16"))
    meds = sorted(res.medoids)
    assert len(set(meds)) == 4 and all(0 <= m < 96 for m in meds)


def test_server_quant_warmup_pretraces_every_variant():
    """The warmup satellite: a quantized server's warmup traces base +
    telemetry quantized variants AND the exact fp32 fallback program, so
    live traffic on warmed buckets never retraces."""
    from repro.launch.serve_medoid import MedoidServer

    srv = MedoidServer(precision="bf16", seed=0, max_batch=4)
    srv.warmup([(48, 6)])
    c0 = srv.recompiles
    for i in range(3):
        # n in 40..42: same power-of-two bucket (64) warmup pre-traced
        srv.submit(jax.random.normal(jax.random.fold_in(
            jax.random.key(9), i), (40 + i, 6)))
    srv.drain()
    stats = srv.stats()
    assert srv.recompiles == c0 == 0          # all variants were pre-traced
    assert stats["answered"] == 3
    assert stats["precision"] == "bf16"
    assert stats["quant_fallbacks"] >= 0


def test_server_rejects_bad_precision():
    from repro.launch.serve_medoid import MedoidServer

    with pytest.raises(ValueError, match="unknown precision"):
        MedoidServer(precision="fp16")
    with pytest.raises(ValueError, match="unknown error model"):
        MedoidServer(precision="bf16", quant_error_model="exact")
