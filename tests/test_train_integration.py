"""End-to-end training integration: loss decreases; microbatch equivalence;
grad compression trains; flash attention inside the full stack."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelCfg
from repro.data.pipeline import batch_at
from repro.models.model import build_model
from repro.train.train_step import TrainCfg, init_train_state, make_train_step

CFG = ModelCfg(name="ti", family="dense", num_layers=2, d_model=64,
               num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128)
SHAPE = InputShape("t", 64, 8, "train")


def _train(tcfg, steps=30, seed=0):
    model = build_model(CFG)
    state = init_train_state(model, jax.random.key(seed), tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    losses = []
    for t in range(steps):
        state, m = step(state, batch_at(CFG, SHAPE, t))
        losses.append(float(m["loss"]))
    return losses, state


def test_loss_decreases():
    tcfg = TrainCfg(peak_lr=3e-3, warmup_steps=3, total_steps=30, remat=True)
    losses, _ = _train(tcfg)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_microbatch_grad_equivalence():
    """1 vs 4 microbatches: same data -> (near-)identical first-step params."""
    t1 = TrainCfg(peak_lr=1e-3, warmup_steps=1, total_steps=5,
                  num_microbatches=1, remat=True)
    t4 = TrainCfg(peak_lr=1e-3, warmup_steps=1, total_steps=5,
                  num_microbatches=4, remat=True)
    model = build_model(CFG)
    s1 = init_train_state(model, jax.random.key(1), t1)
    s4 = init_train_state(model, jax.random.key(1), t4)
    b = batch_at(CFG, SHAPE, 0)
    s1n, m1 = jax.jit(make_train_step(model, t1))(s1, b)
    s4n, m4 = jax.jit(make_train_step(model, t4))(s4, b)
    # losses match (mean over microbatches == full-batch mean)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-2
    for a, b_ in zip(jax.tree.leaves(s1n.params), jax.tree.leaves(s4n.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=0.2, atol=5e-3)


def test_grad_compression_still_trains():
    tcfg = TrainCfg(peak_lr=3e-3, warmup_steps=3, total_steps=30, remat=True,
                    grad_compression=True)
    losses, state = _train(tcfg)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25, losses
    assert state.ef is not None


def test_serve_driver_end_to_end():
    from repro.launch.serve import Request, Server
    srv = Server("internlm2-1.8b", smoke=True, batch_slots=2, max_len=64)
    key = jax.random.key(7)
    reqs = [Request(rid=i,
                    prompt=jax.random.randint(jax.random.fold_in(key, i),
                                              (8,), 0, srv.cfg.vocab_size),
                    max_new=6)
            for i in range(3)]
    out = srv.run(reqs)
    assert out["requests"] == 3
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)
