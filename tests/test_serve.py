"""Live-corpus serving suite: mutable store, incremental maintenance, EDF.

The acceptance properties of the serving subsystem (ISSUE 9):

* **bit-identity** — on a long insert/delete stream, every served answer
  equals recomputing from scratch on that exact corpus version (the
  incremental centralities are exact, and re-runs are keyed by version);
* **O(n) kept mutations** — a mutation that keeps the incumbent costs one
  capacity-bucket n-vector of distance evaluations, asserted via the pull
  odometer on every update record;
* **no retrace on mutate** — an arbitrary mutation stream inside one
  capacity bucket reuses one compiled program per mutation kind (the
  ``"corpus"`` trace odometer stays flat), and re-runs reuse the ragged
  program of their bucket;
* **EDF scheduling** — earliest-deadline-first ordering, priority
  tie-breaks, shed-on-hopeless-deadline, FIFO default unchanged.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.engine import instrument
from repro.serve.corpus import CorpusStore
from repro.serve.maintain import MaintainedMedoid
from repro.serve.scheduler import EdfPolicy, FifoPolicy, LatencyModel, \
    resolve_policy

pytestmark = pytest.mark.serve


def exact_cent(store: CorpusStore) -> np.ndarray:
    """From-scratch centralities of the live snapshot, in live-slot order
    (float32 host recompute — the reference a served answer is judged by)."""
    snap = store.snapshot().astype(np.float32)
    d = np.sqrt(np.maximum(
        ((snap[:, None, :] - snap[None, :, :]) ** 2).sum(-1), 0.0,
        dtype=np.float32))
    return d.sum(1)


def exact_slot(store: CorpusStore) -> int:
    """From-scratch exact medoid slot of the store's current version."""
    return int(store.live_slots()[exact_cent(store).argmin()])


def assert_eps_exact(store: CorpusStore, slot: int) -> None:
    """Served ``slot`` equals the from-scratch medoid, or (exact ties /
    float32 accumulation residue — the corpus-store precision caveat) its
    true centrality is within fractional tolerance of the true minimum."""
    if slot == exact_slot(store):
        return
    cent = exact_cent(store)
    pos = int(np.searchsorted(store.live_slots(), slot))
    lo = float(cent.min())
    assert float(cent[pos]) <= lo + 1e-3 * max(1.0, abs(lo)), \
        f"served slot {slot} is not an eps-exact medoid"


def exact_budget(n_bucket: int) -> int:
    # budget_per_arm >= n_bucket * ceil(log2 n_bucket): every round exact
    return n_bucket * max(1, int(np.ceil(np.log2(n_bucket))))


# ---------------------------------------------------------------------------
# corpus store
# ---------------------------------------------------------------------------

class TestCorpusStore:
    def test_bootstrap_matches_exact(self):
        rng = np.random.default_rng(0)
        data = rng.normal(size=(11, 5)).astype(np.float32)
        store = CorpusStore.from_points(data)
        assert store.n == 11 and store.capacity == 16
        assert store.exact_medoid_slot == exact_slot(store)
        assert store.init_pulls == 16 * 16

    def test_mutations_track_exact_centralities(self):
        rng = np.random.default_rng(1)
        store = CorpusStore.from_points(
            rng.normal(size=(9, 4)).astype(np.float32))
        for step in range(30):
            if store.n <= 4 or (store.n < 14 and rng.random() < 0.6):
                store.insert(rng.normal(size=4).astype(np.float32))
            else:
                store.delete(int(rng.choice(store.live_slots())))
            assert store.exact_medoid_slot == exact_slot(store), \
                f"winner drifted at step {step}"
        assert store.version == 30

    def test_slot_recycling_is_deterministic(self):
        store = CorpusStore(3, capacity=8)
        s0 = store.insert(np.ones(3, np.float32))
        s1 = store.insert(np.full(3, 2, np.float32))
        assert (s0, s1) == (0, 1)         # lowest free slot first
        store.delete(s0)
        assert store.insert(np.zeros(3, np.float32)) == 0   # recycled

    def test_growth_doubles_and_preserves_slots(self):
        rng = np.random.default_rng(2)
        store = CorpusStore.from_points(
            rng.normal(size=(8, 3)).astype(np.float32))
        assert store.capacity == 8 and not store._free
        slots_before = store.live_slots().tolist()
        s = store.insert(rng.normal(size=3).astype(np.float32))
        assert store.capacity == 16 and store.grows == 1
        assert s == 8                      # new slots extend, never remap
        assert store.live_slots().tolist() == slots_before + [8]
        assert store.exact_medoid_slot == exact_slot(store)

    def test_mutation_cost_is_one_capacity_vector(self):
        rng = np.random.default_rng(3)
        store = CorpusStore.from_points(
            rng.normal(size=(10, 4)).astype(np.float32))
        before = store.mutation_pulls
        store.insert(rng.normal(size=4).astype(np.float32))
        assert store.mutation_pulls - before == store.capacity
        before = store.mutation_pulls
        store.delete(0)
        assert store.mutation_pulls - before == store.capacity

    def test_no_retrace_within_capacity_bucket(self):
        rng = np.random.default_rng(4)
        store = CorpusStore.from_points(
            rng.normal(size=(10, 4)).astype(np.float32))
        # warm both mutation kinds at this capacity, then an arbitrary
        # stream must never trace again
        store.insert(rng.normal(size=4).astype(np.float32))
        store.delete(0)
        with instrument.deltas() as d:
            for _ in range(20):
                if store.n < 14 and rng.random() < 0.6:
                    store.insert(rng.normal(size=4).astype(np.float32))
                elif store.n > 4:
                    store.delete(int(rng.choice(store.live_slots())))
            assert store.capacity == 16    # stayed inside the bucket
        assert d.trace("corpus") == 0
        assert d.dispatch("corpus") == 20

    def test_rejects_bad_input(self):
        store = CorpusStore(4)
        with pytest.raises(ValueError):
            store.insert(np.zeros(3, np.float32))     # wrong d
        with pytest.raises(ValueError):
            store.delete(0)                            # not live
        with pytest.raises(ValueError):
            CorpusStore(0)
        with pytest.raises(ValueError):
            CorpusStore(4, metric="nope")


# ---------------------------------------------------------------------------
# incremental maintenance: the acceptance stream
# ---------------------------------------------------------------------------

class TestMaintainedMedoid:
    def test_500_step_stream_every_answer_exact_and_On_when_kept(self):
        """THE acceptance test: a 500-step insert/delete stream where every
        served answer equals the from-scratch exact medoid of that corpus
        version, kept-incumbent mutations cost exactly one capacity
        n-vector, and no mutation inside a capacity bucket retraces."""
        rng = np.random.default_rng(7)
        # capacity pre-sized to the stream's bucket (no mid-stream growth —
        # growth legitimately traces new shapes and has its own test), and
        # n kept in [10, 16] so every re-run shares one ragged bucket
        store = CorpusStore.from_points(
            rng.normal(size=(12, 4)).astype(np.float32), capacity=32)
        mm = MaintainedMedoid(store, budget_per_arm=exact_budget(32), seed=3)
        # warm every program this stream can touch: both mutation kinds at
        # this capacity (the bootstrap already ran the re-run path)
        mm.insert(rng.normal(size=4).astype(np.float32))
        mm.delete(int(rng.choice(store.live_slots())))
        with instrument.deltas() as d:
            for step in range(500):
                if store.n <= 10 or (store.n < 16 and rng.random() < 0.55):
                    upd = mm.insert(rng.normal(size=4).astype(np.float32))
                else:
                    upd = mm.delete(int(rng.choice(store.live_slots())))
                slot, version = mm.query()
                assert slot == upd.medoid_slot
                assert slot == exact_slot(store), \
                    f"served answer wrong at step {step} (version {version})"
                if not upd.reran:
                    assert upd.reason == "kept"
                    assert upd.pulls == store.capacity, \
                        "kept mutation must cost exactly one n-vector"
            assert store.capacity == 32    # stream stayed in one bucket
        # no mutation inside the capacity bucket traced ANY program: the
        # corpus mutation kernels and the re-run's gather + ragged programs
        # were all warmed before the stream started
        assert d.trace("corpus") == 0
        assert d.trace("ragged") == 0
        assert mm.kept > 0 and mm.reruns > 0      # both paths exercised

    def test_rerun_bit_identical_to_fresh_run_on_same_version(self):
        """A re-run's answer is reproducible from (seed, version) alone:
        an independent MaintainedMedoid adopting a copy of the same corpus
        at the same version serves the identical slot."""
        rng = np.random.default_rng(8)
        data = rng.normal(size=(13, 6)).astype(np.float32)
        a = MaintainedMedoid(CorpusStore.from_points(data),
                             budget_per_arm=8, seed=11)
        b = MaintainedMedoid(CorpusStore.from_points(data),
                             budget_per_arm=8, seed=11)
        # modest budget (NOT the exact regime): equality must come from the
        # version-keyed rerun protocol, not from exactness
        for step in range(12):
            x = rng.normal(size=6).astype(np.float32)
            ua, ub = a.insert(x), b.insert(x)
            assert ua == ub
            assert a.query() == b.query()

    def test_deleted_incumbent_forces_rerun(self):
        rng = np.random.default_rng(9)
        store = CorpusStore.from_points(
            rng.normal(size=(10, 4)).astype(np.float32))
        mm = MaintainedMedoid(store, budget_per_arm=exact_budget(16))
        incumbent = mm.medoid_slot
        upd = mm.delete(incumbent)
        assert upd.reran and upd.reason == "deleted_incumbent"
        assert mm.query()[0] == exact_slot(store)

    def test_empty_and_refill(self):
        mm = MaintainedMedoid(d=3, budget_per_arm=exact_budget(8))
        assert mm.query() == (None, 0)
        mm.insert(np.zeros(3, np.float32))
        assert mm.query()[0] == 0
        upd = mm.delete(0)
        assert upd.reason == "emptied" and mm.query()[0] is None
        mm.insert(np.ones(3, np.float32))
        assert mm.query()[0] is not None

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_interleaving_linearizability(self, seed):
        """Property: ANY interleaving of inserts and deletes serves, after
        every mutation, the (eps-)exact medoid of that corpus version —
        i.e. the mutable store is linearizable against
        recompute-from-scratch, up to the float32 tie caveat."""
        rng = np.random.default_rng(seed)
        n0 = int(rng.integers(1, 10))
        store = CorpusStore.from_points(
            rng.normal(size=(n0, 3)).astype(np.float32))
        mm = MaintainedMedoid(store, budget_per_arm=exact_budget(32))
        for _ in range(25):
            if store.n == 0 or rng.random() < 0.6:
                mm.insert(rng.normal(size=3).astype(np.float32))
            else:
                mm.delete(int(rng.choice(store.live_slots())))
            slot, _ = mm.query()
            if store.n == 0:
                assert slot is None
            else:
                assert_eps_exact(store, slot)

    def test_facade_builder(self):
        from repro.api import maintain_medoid

        rng = np.random.default_rng(10)
        mm = maintain_medoid(rng.normal(size=(9, 4)).astype(np.float32),
                             budget_per_arm=exact_budget(16))
        assert mm.query()[0] == exact_slot(mm.store)
        mm2 = maintain_medoid(d=4)
        assert mm2.query() == (None, 0)
        with pytest.raises(ValueError):
            maintain_medoid()
        with pytest.raises(ValueError):
            maintain_medoid(d=4, algo="exact")


# ---------------------------------------------------------------------------
# scheduling: latency model + policies (pure host objects)
# ---------------------------------------------------------------------------

class _Req:
    def __init__(self, rid, bucket="64x8", priority=0, deadline_s=None):
        self.rid = rid
        self.bucket = bucket
        self.priority = priority
        self.deadline_s = deadline_s


def _bkey(r):
    return r.bucket


class TestScheduling:
    def test_resolve_policy(self):
        assert isinstance(resolve_policy("fifo"), FifoPolicy)
        assert isinstance(resolve_policy("edf"), EdfPolicy)
        p = EdfPolicy()
        assert resolve_policy(p) is p
        with pytest.raises(ValueError):
            resolve_policy("lifo")
        with pytest.raises(TypeError):
            resolve_policy(42)

    def test_latency_model_never_invents(self):
        from repro.obs import ServerMetrics

        m = ServerMetrics()
        model = LatencyModel(m, quantile=0.9)
        assert model.estimate("64x8", compiled=True) is None
        assert model.estimate("64x8", compiled=False) is None
        # steady data for one bucket; unseen buckets price as worst compile
        m.latency.labels("64x8", "steady").observe(0.004)
        m.latency.labels("64x8", "compile").observe(1.7)
        assert model.estimate("64x8", compiled=True) == pytest.approx(0.005)
        assert model.estimate("256x8", compiled=False) == pytest.approx(2.0)

    def test_fifo_is_arrival_order_bucket_group(self):
        q = [_Req(0, "a"), _Req(1, "b"), _Req(2, "a"), _Req(3, "a")]
        batch, rest, shed = FifoPolicy().select(
            q, now=0.0, max_batch=2, bucket_key=_bkey,
            estimate=lambda r: None)
        assert [r.rid for r in batch] == [0, 2]     # head's bucket-mates
        assert [r.rid for r in rest] == [1, 3]
        assert shed == []

    def test_edf_orders_by_deadline_then_priority_then_arrival(self):
        q = [_Req(0, "a", deadline_s=9.0), _Req(1, "a", deadline_s=5.0),
             _Req(2, "a", deadline_s=5.0, priority=3), _Req(3, "a")]
        batch, rest, shed = EdfPolicy().select(
            q, now=0.0, max_batch=3, bucket_key=_bkey,
            estimate=lambda r: None)
        # earliest deadline first; priority breaks the 5.0 tie; undated last
        assert [r.rid for r in batch] == [2, 1, 0]
        assert [r.rid for r in rest] == [3]
        assert shed == []

    def test_edf_picks_most_urgent_bucket(self):
        q = [_Req(0, "a"), _Req(1, "b", deadline_s=1.0), _Req(2, "b")]
        batch, rest, _ = EdfPolicy().select(
            q, now=0.0, max_batch=4, bucket_key=_bkey,
            estimate=lambda r: None)
        assert [r.rid for r in batch] == [1, 2]     # urgent bucket's mates
        assert [r.rid for r in rest] == [0]

    def test_edf_sheds_hopeless_deadlines(self):
        q = [_Req(0, deadline_s=0.5),                 # already passed
             _Req(1, deadline_s=2.0),                 # infeasible: est 1.5
             _Req(2, deadline_s=9.0), _Req(3)]        # fine / best-effort
        batch, rest, shed = EdfPolicy().select(
            q, now=1.0, max_batch=4, bucket_key=_bkey,
            estimate=lambda r: 1.5)
        assert [r.rid for r in shed] == [0, 1]
        assert [r.rid for r in batch] == [2, 3]
        assert rest == []

    def test_edf_never_sheds_unpriced_requests(self):
        q = [_Req(0, deadline_s=2.0)]
        batch, _, shed = EdfPolicy().select(
            q, now=1.99, max_batch=1, bucket_key=_bkey,
            estimate=lambda r: None)
        assert shed == [] and [r.rid for r in batch] == [0]


# ---------------------------------------------------------------------------
# the server: policies, deadlines, gaps, warmup
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class TestMedoidServer:
    def test_edf_serves_earliest_deadline_first(self):
        from repro.launch.serve_medoid import MedoidServer

        clock = FakeClock()
        srv = MedoidServer(budget_per_arm=8, max_batch=2, policy="edf",
                           clock=clock, collect_gaps=False)
        key = jax.random.key(0)
        qa = jax.random.normal(key, (16, 4))
        qb = jax.random.normal(jax.random.fold_in(key, 1), (64, 4))
        r0 = srv.submit(qa)                                 # best-effort
        r1 = srv.submit(qb, deadline_s=50.0)
        r2 = srv.submit(qb, deadline_s=10.0, priority=1)    # most urgent
        first = srv.step()
        # the urgent 64-bucket group went first despite arriving last
        assert {q.rid for q in first} == {r1, r2}
        second = srv.step()
        assert [q.rid for q in second] == [r0]
        assert srv.done[r2].deadline_met is True
        assert srv.done[r0].deadline_met is None            # no deadline
        assert srv.stats()["policy"] == "edf"

    def test_edf_sheds_expired_requests(self):
        from repro.launch.serve_medoid import MedoidServer

        clock = FakeClock(100.0)
        srv = MedoidServer(budget_per_arm=8, max_batch=2, policy="edf",
                           clock=clock, collect_gaps=False)
        key = jax.random.key(1)
        dead = srv.submit(jax.random.normal(key, (16, 4)), deadline_s=99.0)
        live = srv.submit(jax.random.normal(key, (16, 4)), deadline_s=999.0)
        out = srv.step()
        assert [q.rid for q in out] == [live]
        assert dead in srv.shed and srv.shed[dead].shed
        assert srv.shed[dead].medoid is None
        assert srv.stats()["shed"] == 1
        # shed ids stay burned: resubmitting the rid is a duplicate
        with pytest.raises(ValueError):
            srv.submit(jax.random.normal(key, (16, 4)), rid=dead)
        # metrics recorded the shed + missed deadline
        text = srv.exposition()
        assert "medoid_shed_total" in text
        assert 'medoid_deadline_total{bucket="16x4",outcome="missed"} 1' \
            in text

    def test_fifo_default_ignores_deadlines(self):
        from repro.launch.serve_medoid import MedoidServer

        srv = MedoidServer(budget_per_arm=8, max_batch=2,
                           collect_gaps=False)
        key = jax.random.key(2)
        r0 = srv.submit(jax.random.normal(key, (16, 4)))
        r1 = srv.submit(jax.random.normal(key, (64, 4)), deadline_s=0.001,
                        priority=99)
        out = srv.step()
        assert [q.rid for q in out] == [r0]       # arrival order, no shed
        assert srv.stats()["policy"] == "fifo" and not srv.shed
        srv.drain()
        assert srv.done[r1].deadline_met is False  # recorded, not acted on

    def test_warmup_covers_both_program_variants(self):
        from repro.launch.serve_medoid import MedoidServer

        # gap collection ON (the default): dispatches ride the telemetry
        # variant — a warmed server's first metered step must not trace
        srv = MedoidServer(budget_per_arm=8, max_batch=2)
        srv.warmup([(40, 6)])
        srv.submit(jax.random.normal(jax.random.key(3), (40, 6)))
        with instrument.deltas() as d:
            srv.step()
        assert d.trace("ragged") == 0
        assert srv.recompiles == 0

    def test_gap_histogram_lands_in_exposition_and_validates(self, tmp_path):
        from repro.launch.serve_medoid import MedoidServer
        from repro.obs.validate import validate_exposition

        srv = MedoidServer(budget_per_arm=8, max_batch=2)   # gaps on
        key = jax.random.key(4)
        for i in range(3):
            srv.submit(jax.random.normal(jax.random.fold_in(key, i), (32, 4)))
        srv.drain()
        assert all(q.gap is not None for q in srv.done.values())
        text = srv.exposition()
        assert "medoid_winner_gap_bucket" in text
        path = tmp_path / "metrics.txt"
        path.write_text(text)
        summary = validate_exposition(str(path))
        assert summary["samples"] > 0

    def test_gap_collection_keeps_answers_bit_identical(self):
        from repro.launch.serve_medoid import MedoidServer

        key = jax.random.key(5)
        queries = [jax.random.normal(jax.random.fold_in(key, i), (24, 4))
                   for i in range(4)]
        answers = {}
        for gaps in (False, True):
            srv = MedoidServer(budget_per_arm=8, max_batch=2, seed=9,
                               collect_gaps=gaps)
            for q in queries:
                srv.submit(q)
            srv.drain()
            answers[gaps] = [srv.done[r].medoid for r in sorted(srv.done)]
        assert answers[False] == answers[True]


# ---------------------------------------------------------------------------
# streaming cluster maintenance
# ---------------------------------------------------------------------------

class TestClusterStream:
    def test_arrivals_assigned_to_nearest_medoid(self):
        from repro.cluster.service import ClusterStream

        rng = np.random.default_rng(11)
        data = rng.normal(size=(60, 4)).astype(np.float32)
        cs = ClusterStream(data, 3, jax.random.key(0))
        pts = rng.normal(size=(5, 4)).astype(np.float32)
        meds_before = cs.data[cs.medoids].copy()
        out = cs.add(pts)
        want = np.linalg.norm(pts[:, None, :] - meds_before[None, :, :],
                              axis=-1).argmin(1)
        np.testing.assert_array_equal(out["assigned"], want)
        assert cs.n == 65 and cs.arrivals == 5
        assert sorted(set(want.tolist())) == out["affected"]

    def test_only_affected_clusters_rerefine(self):
        from repro.cluster.service import ClusterStream

        rng = np.random.default_rng(12)
        # two tight, well-separated blobs: arrivals near blob 1 only
        data = np.concatenate([
            rng.normal(size=(30, 3)).astype(np.float32) - 10.0,
            rng.normal(size=(30, 3)).astype(np.float32) + 10.0])
        cs = ClusterStream(data, 2, jax.random.key(1))
        blob1 = int(cs.labels[-1])
        other = 1 - blob1
        med_other = cs.medoids[other]
        out = cs.add(rng.normal(size=(6, 3)).astype(np.float32) + 10.0)
        assert out["affected"] == [blob1]
        assert cs.medoids[other] == med_other     # untouched cluster stable

    def test_assign_program_is_shape_bucketed(self):
        from repro.cluster.kmedoids import assign_to_medoids

        meds = np.eye(3, dtype=np.float32)
        rng = np.random.default_rng(13)
        # arrival sizes 3 and 7 share the padded 8-bucket: labels agree
        # with numpy and padded pulls are charged honestly
        for m in (3, 7):
            pts = rng.normal(size=(m, 3)).astype(np.float32)
            labels, d1, pulls = assign_to_medoids(pts, meds)
            want = np.linalg.norm(pts[:, None, :] - meds[None, :, :],
                                  axis=-1).argmin(1)
            np.testing.assert_array_equal(labels, want)
            assert pulls == 8 * 3

    def test_stream_route_on_cluster_service(self):
        from repro.cluster.service import ClusterService, ClusterStream
        from repro.launch.serve_medoid import MedoidServer

        rng = np.random.default_rng(14)
        srv = MedoidServer(budget_per_arm=8, collect_gaps=False)
        cs = ClusterStream(rng.normal(size=(40, 3)).astype(np.float32), 2,
                           jax.random.key(2))
        svc = ClusterService(srv)
        assert "/stream" not in svc.routes()
        with pytest.raises(KeyError):
            svc.handle("/stream")
        svc.attach_stream(cs)
        assert "/stream" in svc.routes()
        cs.add(rng.normal(size=(4, 3)).astype(np.float32))
        payload = svc.handle("/stream")
        assert payload["arrivals"] == 4 and payload["n"] == 44
        assert payload["total_pulls"] == cs.pulls

    def test_refit_resets_from_current_store(self):
        from repro.cluster.service import ClusterStream

        rng = np.random.default_rng(15)
        cs = ClusterStream(rng.normal(size=(30, 3)).astype(np.float32), 2,
                           jax.random.key(3))
        cs.add(rng.normal(size=(10, 3)).astype(np.float32) + 5.0)
        fit = cs.refit()
        assert len(cs.labels) == cs.n == 40
        assert cs.medoids == list(fit.medoids)


# ---------------------------------------------------------------------------
# the mutation-stream driver (CI's serve-smoke entry)
# ---------------------------------------------------------------------------

class TestStreamDriver:
    def test_run_stream_verifies_and_artifacts_validate(self, tmp_path):
        from repro.obs import TraceSession
        from repro.obs.validate import validate_exposition, validate_trace
        from repro.serve.stream import StreamMetrics, exact_budget_per_arm, \
            run_stream

        rng = np.random.default_rng(16)
        store = CorpusStore.from_points(
            rng.normal(size=(10, 4)).astype(np.float32))
        mm = MaintainedMedoid(store,
                              budget_per_arm=exact_budget_per_arm(60, 8))
        trace_path = tmp_path / "stream.jsonl"
        metrics_path = tmp_path / "metrics.txt"
        metrics = StreamMetrics()
        with TraceSession(str(trace_path),
                          meta={"workload": "serve_stream"}) as session:
            out = run_stream(mm, steps=50, seed=16, verify=True,
                             metrics=metrics, trace=session)
        assert out["verified"] == 50
        # +1: adopting the pre-populated store cost one bootstrap re-run
        assert out["kept"] + out["reruns"] == 50 + 1
        metrics_path.write_text(metrics.exposition())
        assert validate_trace(str(trace_path))["selects"] == 50
        assert validate_exposition(str(metrics_path))["families"] >= 4
        text = metrics_path.read_text()
        assert "corpus_mutations_total" in text
        assert "corpus_pulls_total" in text
