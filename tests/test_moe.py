"""MoE dispatch correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoECfg
from repro.models.moe import moe_apply, moe_init


def _setup(E=4, K=2, d=16, d_e=32, cap=8.0, shared=0, seed=0):
    cfg = MoECfg(num_experts=E, top_k=K, d_expert=d_e, capacity_factor=cap,
                 num_shared=shared)
    params = moe_init(jax.random.key(seed), d, cfg, d_e, jnp.float32)
    return cfg, params


def _dense_reference(params, x, cfg):
    """All-experts reference: y = sum_e gate_e(x) * expert_e(x) over top-k."""
    B, S, d = x.shape
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    outs = []
    for e in range(cfg.num_experts):
        h = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        outs.append(h @ params["w_down"][e])
    outs = jnp.stack(outs, axis=2)           # (B,S,E,d)
    y = jnp.zeros_like(x)
    for k in range(cfg.top_k):
        sel = jnp.take_along_axis(outs, gi[..., k][..., None, None], axis=2)[:, :, 0]
        y = y + gv[..., k][..., None] * sel
    return y


def test_moe_matches_dense_reference_with_ample_capacity():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.key(1), (2, 24, 16))
    got, aux = moe_apply(params, x, cfg, group=8)
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg, params = _setup(cap=0.26)   # tight capacity -> drops
    x = jax.random.normal(jax.random.key(2), (1, 32, 16))
    got, _ = moe_apply(params, x, cfg, group=32)
    want = _dense_reference(params, x, cfg)
    # some tokens dropped => outputs differ, but bounded (zeros, not garbage)
    diff = np.abs(np.asarray(got) - np.asarray(want)).max()
    assert diff > 1e-3
    assert np.isfinite(np.asarray(got)).all()


def test_moe_shared_expert_always_on():
    cfg, params = _setup(shared=1)
    x = jnp.zeros((1, 4, 16))
    # zero input -> router uniform; shared expert of zeros -> zero; finite
    got, _ = moe_apply(params, x, cfg)
    assert np.isfinite(np.asarray(got)).all()


def test_moe_grouping_invariance():
    cfg, params = _setup(cap=16.0)   # lossless
    x = jax.random.normal(jax.random.key(3), (2, 32, 16))
    a, _ = moe_apply(params, x, cfg, group=8)
    b, _ = moe_apply(params, x, cfg, group=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)
