"""Distance-backend layer: registry semantics + cross-backend parity.

Every registered backend must agree with ``repro.core.distances`` on both
round primitives (pairwise block, centrality sums) for all four metrics, on
shapes that are exact kernel-block multiples and shapes that force padding —
and the engines must return *identical* medoids under every backend for a
fixed key (the backends differ in memory traffic, never in answers).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (METRICS, corr_sh_medoid, corr_sh_medoid_batch,
                        exact_medoid, get_backend, list_backends, pairwise,
                        register_backend)
from repro.core.backend import DistanceBackend

# exact fp32 backends only: the quantized backends (repro.quant)
# are perturbed estimators by design — their parity/determinism
# contracts live in tests/test_quant.py and the quant section of
# tests/test_backends.py, at quantization-error tolerances
BACKENDS = [b for b in list_backends() if not b.startswith("quant_")]

# one block-aligned shape (BC=128, BR=128, BD=256) and two ragged ones
SHAPES = [(128, 128, 256), (130, 67, 40), (3, 5, 2)]


def _data(c, r, d, seed=0):
    k = jax.random.key(seed)
    x = jax.random.normal(jax.random.fold_in(k, 1), (c, d))
    y = jax.random.normal(jax.random.fold_in(k, 2), (r, d))
    return x, y


# ------------------------------- registry ----------------------------------

def test_registry_contents():
    assert {"reference", "pallas_pairwise", "pallas_fused"} <= set(BACKENDS)
    assert get_backend(None).name == "reference"
    assert get_backend("pallas_fused") is get_backend("pallas_fused")
    assert not get_backend("pallas_fused").materializes_block
    assert get_backend("reference").materializes_block


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("no_such_backend")
    with pytest.raises(ValueError):
        corr_sh_medoid(jnp.zeros((4, 2)), jax.random.key(0), budget=40,
                       backend="no_such_backend")


def test_register_custom_backend():
    doubled = DistanceBackend(
        name="_test_doubled",
        pairwise=lambda m: lambda x, y: 2.0 * pairwise(m)(x, y),
        centrality_sums=lambda m: lambda x, y: 2.0 * jnp.sum(
            pairwise(m)(x, y), axis=1),
        materializes_block=True)
    register_backend(doubled)
    assert get_backend("_test_doubled") is doubled
    # scaling every distance by 2 is order-preserving: same medoid
    x = jax.random.normal(jax.random.key(0), (64, 8))
    a = int(corr_sh_medoid(x, jax.random.key(1), budget=64 * 20))
    b = int(corr_sh_medoid(x, jax.random.key(1), budget=64 * 20,
                           backend="_test_doubled"))
    assert a == b


# ------------------------------ primitive parity ---------------------------

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_pairwise_parity(backend, shape, metric):
    x, y = _data(*shape, seed=sum(shape))
    got = get_backend(backend).pairwise(metric)(x, y)
    want = pairwise(metric)(x, y)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_centrality_parity(backend, shape, metric):
    x, y = _data(*shape, seed=sum(shape) + 1)
    got = get_backend(backend).centrality_sums(metric)(x, y)
    want = jnp.sum(pairwise(metric)(x, y), axis=1)
    assert got.shape == (shape[0],)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=5e-3 * shape[1])


# ------------------------------- engine parity -----------------------------

@pytest.mark.parametrize("metric", METRICS)
def test_corr_sh_medoid_same_under_every_backend(metric):
    x = jax.random.normal(jax.random.key(4), (200, 24))
    key = jax.random.key(11)
    medoids = {b: int(corr_sh_medoid(x, key, budget=200 * 25, metric=metric,
                                     backend=b))
               for b in ("reference", "pallas_pairwise", "pallas_fused")}
    assert len(set(medoids.values())) == 1, medoids


@pytest.mark.parametrize("backend", ["reference", "pallas_fused"])
def test_batch_engine_matches_exact_and_single(backend):
    b, n, d = 3, 96, 12
    data = jax.random.normal(jax.random.key(6), (b, n, d))
    key = jax.random.key(8)
    # exact budget -> every query's answer is the true medoid
    got = corr_sh_medoid_batch(data, key, budget=n * n * 10, metric="l2",
                               backend=backend)
    want = [int(exact_medoid(data[i], "l2")) for i in range(b)]
    assert [int(m) for m in got] == want
    # halving budget -> each query matches the single-query engine run with
    # the same per-query derived key (batch = vmap of the same round loop)
    keys = jax.random.split(key, b)
    got_h = corr_sh_medoid_batch(data, key, budget=n * 20, metric="l2",
                                 backend=backend)
    singles = [int(corr_sh_medoid(data[i], keys[i], budget=n * 20,
                                  metric="l2", backend=backend))
               for i in range(b)]
    assert [int(m) for m in got_h] == singles


def test_batch_engine_rejects_unbatched_input():
    with pytest.raises(ValueError, match="expected"):
        corr_sh_medoid_batch(jnp.zeros((8, 4)), jax.random.key(0), budget=80)


@pytest.mark.ragged
@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_ragged_same_medoids_under_every_backend(metric):
    """Deterministic-seed regression for the ragged path: a fixed key must
    produce identical medoids under reference / pallas_pairwise /
    pallas_fused (backends differ in memory traffic, never in answers) —
    and rerunning any backend with the same key reproduces them."""
    from repro.core import corr_sh_medoid_ragged, pack_queries

    qs = [jax.random.normal(jax.random.fold_in(jax.random.key(13), i), (n, 10))
          for i, n in enumerate((9, 64, 33, 2))]
    data, lengths = pack_queries(qs)
    key = jax.random.key(21)
    meds = {b: [int(m) for m in
                corr_sh_medoid_ragged(data, lengths, key, budget=64 * 15,
                                      metric=metric, backend=b)]
            for b in ("reference", "pallas_pairwise", "pallas_fused")}
    assert meds["reference"] == meds["pallas_pairwise"] == meds["pallas_fused"]
    for i, q in enumerate(qs):
        assert 0 <= meds["reference"][i] < q.shape[0]
    rerun = [int(m) for m in
             corr_sh_medoid_ragged(data, lengths, key, budget=64 * 15,
                                   metric=metric, backend="pallas_fused")]
    assert rerun == meds["pallas_fused"]


# --------------------- quantized backends (repro.quant) ---------------------
# Excluded from the fp32 parametrizations above on purpose: quantized
# estimates are PERTURBED by design. Their contracts are (a) registry
# resolution through the plugin hook, (b) agreement with the reference
# block at quantization-error tolerances, (c) bit-exact determinism —
# the same inputs quantize identically on every call and across the
# jnp/Pallas implementations of the same precision.

QUANT_BACKENDS = ("quant_bf16", "quant_int8", "quant_bf16_fused")


@pytest.mark.quant
def test_quant_registry_resolution():
    """The quant backends register lazily through the plugin hook: both
    get_backend by name and the precision->backend mapping resolve."""
    from repro.quant import backend_for

    for name in QUANT_BACKENDS:
        assert get_backend(name).name == name
        assert get_backend(name) is get_backend(name)
    assert set(QUANT_BACKENDS) <= set(list_backends())
    assert backend_for("fp32") is None
    assert backend_for("bf16") == "quant_bf16"
    assert backend_for("bf16", base="pallas_fused") == "quant_bf16_fused"
    assert backend_for("int8", base="pallas_fused") == "quant_int8"
    with pytest.raises(ValueError, match="unknown precision"):
        backend_for("fp8")


@pytest.mark.quant
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("backend", QUANT_BACKENDS)
def test_quant_pairwise_tracks_reference(backend, metric):
    """Quantized blocks agree with the reference block at quantization-error
    tolerances (bf16: ~2^-8 relative on the Gram; int8: per-row-scale
    rounding) — loose enough for the perturbation, tight enough to catch a
    wrong epilogue or a dropped dequantization scale."""
    x, y = _data(130, 67, 24, seed=5)
    got = get_backend(backend).pairwise(metric)(x, y)
    want = pairwise(metric)(x, y)
    assert got.shape == want.shape
    tol = 0.02 if "bf16" in backend else 0.2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.quant
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("backend", QUANT_BACKENDS)
def test_quant_determinism(backend, metric):
    """Same inputs -> bit-identical outputs on every call (quantization is
    a pure function; no data-dependent rounding state)."""
    x, y = _data(66, 34, 12, seed=9)
    be = get_backend(backend)
    a = np.asarray(be.centrality_sums(metric)(x, y))
    b = np.asarray(be.centrality_sums(metric)(x, y))
    np.testing.assert_array_equal(a, b)
    p1 = np.asarray(be.pairwise(metric)(x, y))
    p2 = np.asarray(be.pairwise(metric)(x, y))
    np.testing.assert_array_equal(p1, p2)


@pytest.mark.quant
@pytest.mark.parametrize("metric", METRICS)
def test_quant_bf16_fused_matches_jnp_bf16(metric):
    """The Pallas in-kernel-cast centrality and the jnp bf16 path compute
    the same quantity (bf16-rounded inputs, fp32 accumulation); kernel
    blocking may reorder fp32 adds, so equality is near-bit, not bit."""
    x, y = _data(96, 80, 16, seed=3)
    a = get_backend("quant_bf16").centrality_sums(metric)(x, y)
    b = get_backend("quant_bf16_fused").centrality_sums(metric)(x, y)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)
