"""Med-dit / RAND / exact baselines + hardness statistics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (exact_medoid, exact_theta, hardness_stats,
                        meddit_medoid, predicted_error_bound, rand_medoid)
from repro.core.distances import full_distance_matrix


def _clustered(n=384, d=48, seed=0):
    x = jax.random.normal(jax.random.key(seed), (n, d))
    return x.at[: n // 2].mul(0.3)


def test_exact_theta_matches_matrix():
    x = _clustered(130, 17)
    dm = full_distance_matrix(x, "l2")
    np.testing.assert_allclose(exact_theta(x, "l2"),
                               jnp.mean(dm, axis=1), rtol=1e-5)


def test_exact_medoid_blocked_vs_direct():
    x = _clustered(517, 29, seed=3)   # non-multiple of block
    dm = full_distance_matrix(x, "l1")
    assert int(exact_medoid(x, "l1", block=128)) == int(jnp.argmin(jnp.sum(dm, 1)))


def test_meddit_converges_to_central_arm():
    """Med-dit under a budget cap lands in the top ranks of true centrality —
    and (the paper's observation) needs far more pulls than corrSH to fully
    separate close arms, so exact identification is NOT asserted here."""
    x = _clustered()
    hs = hardness_stats(x, "l2")
    truth = int(exact_medoid(x, "l2"))
    res = meddit_medoid(x, jax.random.key(1), metric="l2",
                        sigma=float(hs.sigma), batch=32,
                        max_pulls=384 * 400)
    theta = exact_theta(x, "l2")
    got = int(res.medoid)
    rank = int(jnp.sum(theta < theta[got]))
    assert got == truth or rank <= 10, (got, truth, rank)
    assert int(res.pulls) <= 384 * 400


def test_rand_medoid_reasonable():
    x = _clustered(seed=5)
    truth = int(exact_medoid(x, "l2"))
    theta = exact_theta(x, "l2")
    got = int(rand_medoid(x, jax.random.key(2), num_refs=300, metric="l2"))
    # RAND with many refs should land in the top percentile of centrality
    rank = int(jnp.sum(theta < theta[got]))
    assert got == truth or rank <= 4


def test_hardness_stats_sanity():
    x = _clustered(seed=7)
    hs = hardness_stats(x, "l2")
    assert float(hs.sigma) > 0
    assert float(hs.delta[0]) == 0.0
    assert (np.diff(np.asarray(hs.theta)) >= -1e-6).all()  # sorted
    assert float(hs.h2) > 0 and float(hs.h2_tilde) > 0
    # the paper's gain: correlation helps on clustered data
    assert float(hs.h2 / hs.h2_tilde) > 1.0


def test_predicted_error_bound_monotone():
    x = _clustered(seed=9)
    hs = hardness_stats(x, "l2")
    b_small = float(predicted_error_bound(384, 384 * 10, hs))
    b_large = float(predicted_error_bound(384, 384 * 1000, hs))
    assert 0.0 <= b_large <= b_small <= 1.0
