"""Algorithm 1 (Correlated Sequential Halving): unit + property tests."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (correlated_sequential_halving, corr_sh_medoid,
                        exact_medoid, round_schedule, schedule_pulls)
from repro.data.medoid_datasets import planted_medoid


# ------------------------------- schedule ----------------------------------

@given(n=st.integers(2, 5000), per_arm=st.integers(1, 200))
@settings(max_examples=200, deadline=None)
def test_schedule_respects_budget(n, per_arm):
    budget = per_arm * n
    rounds = round_schedule(n, budget)
    assert rounds, "at least one round"
    # paper: t_r = clip(floor(T / (|S_r| ceil(log2 n))), 1, n); with the
    # t_r >= 1 floor, tiny budgets may exceed T, but never n * ceil(log2 n).
    log2n = max(1, math.ceil(math.log2(n)))
    assert schedule_pulls(n, budget) <= max(budget, n * log2n) + n


@given(n=st.integers(2, 5000))
@settings(max_examples=100, deadline=None)
def test_schedule_halves(n):
    rounds = round_schedule(n, 50 * n)
    for a, b in zip(rounds, rounds[1:]):
        assert b.survivors == math.ceil(a.survivors / 2)
    assert rounds[0].survivors == n


@given(n=st.integers(2, 2000))
@settings(max_examples=50, deadline=None)
def test_schedule_exact_branch_with_huge_budget(n):
    # budget >= n^2 log2 n => t_0 == n: one exact round, output immediately
    rounds = round_schedule(n, n * n * (math.ceil(math.log2(n)) or 1))
    assert rounds[0].exact
    assert len(rounds) == 1


# ------------------------------ correctness --------------------------------

def test_exact_branch_equals_exact_medoid():
    key = jax.random.key(0)
    x = jax.random.normal(key, (257, 33))
    res = correlated_sequential_halving(x, budget=257 * 257 * 20,
                                        key=jax.random.key(1), metric="l2")
    assert int(res.medoid) == int(exact_medoid(x, "l2"))
    assert len(res.rounds) == 1 and res.rounds[0].exact


@pytest.mark.parametrize("metric", ["l1", "l2", "sql2", "cosine"])
def test_finds_planted_medoid(metric):
    key = jax.random.key(3)
    x = planted_medoid(key, 512, 64, gap=3.0)
    truth = int(exact_medoid(x, metric))
    hits = 0
    for s in range(5):
        res = correlated_sequential_halving(
            x, budget=512 * 64, key=jax.random.key(100 + s), metric=metric)
        hits += int(res.medoid) == truth
    assert hits >= 4, f"corrSH too unreliable for {metric}: {hits}/5"


def test_error_decays_with_budget():
    """The paper's central claim: error probability decays (roughly
    exponentially) in budget."""
    key = jax.random.key(9)
    x = jax.random.normal(key, (256, 32))
    x = x.at[: 128].mul(0.3)
    truth = int(exact_medoid(x, "l2"))
    errs = []
    for per_arm in (4, 16, 64):
        wrong = 0
        for s in range(20):
            m = int(corr_sh_medoid(x, jax.random.key(1000 + s),
                                   budget=per_arm * 256, metric="l2"))
            wrong += m != truth
        errs.append(wrong)
    assert errs[0] >= errs[-1]
    assert errs[-1] <= 2


def test_determinism():
    x = jax.random.normal(jax.random.key(5), (128, 16))
    a = int(corr_sh_medoid(x, jax.random.key(7), budget=128 * 20))
    b = int(corr_sh_medoid(x, jax.random.key(7), budget=128 * 20))
    assert a == b


@given(n=st.integers(1, 65))
@settings(max_examples=20, deadline=None)
def test_small_n_never_crashes(n):
    x = jax.random.normal(jax.random.key(n), (n, 8))
    res = correlated_sequential_halving(x, budget=20 * max(n, 1),
                                        key=jax.random.key(0))
    assert 0 <= int(res.medoid) < n


def test_permutation_equivariance():
    """Medoid index should track a permutation of the dataset (exact branch)."""
    key = jax.random.key(11)
    x = jax.random.normal(key, (64, 8))
    perm = jax.random.permutation(jax.random.key(12), 64)
    big = 64 * 64 * 10
    m1 = int(correlated_sequential_halving(x, big, jax.random.key(1)).medoid)
    m2 = int(correlated_sequential_halving(x[perm], big, jax.random.key(1)).medoid)
    assert int(perm[m2]) == m1


def test_kernel_backed_matches_jnp():
    from repro.kernels import ops as kops
    x = jax.random.normal(jax.random.key(2), (200, 48))
    a = correlated_sequential_halving(x, 200 * 30, jax.random.key(3), "l2")
    b = correlated_sequential_halving(x, 200 * 30, jax.random.key(3), "l2",
                                      pairwise_fn=kops.pairwise_kernel("l2"))
    assert int(a.medoid) == int(b.medoid)
