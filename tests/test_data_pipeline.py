"""Deterministic shardable data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import InputShape
from repro.data.pipeline import DataCfg, batch_at, stream

CFG = get_smoke_config("internlm2-1.8b")
SHAPE = InputShape("t", 32, 8, "train")


def test_deterministic():
    a = batch_at(CFG, SHAPE, 5)
    b = batch_at(CFG, SHAPE, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_steps_differ():
    a = batch_at(CFG, SHAPE, 5)["tokens"]
    b = batch_at(CFG, SHAPE, 6)["tokens"]
    assert not np.array_equal(a, b)


def test_skip_to_step_resume():
    """stream(start_step=k) reproduces the tail of stream(start_step=0) —
    the O(1) fault-tolerant resume property."""
    it0 = stream(CFG, SHAPE, start_step=0)
    full = [next(it0)["tokens"] for _ in range(6)]
    it3 = stream(CFG, SHAPE, start_step=3)
    for t in range(3, 6):
        np.testing.assert_array_equal(next(it3)["tokens"], full[t])


def test_dp_ranks_disjoint_and_shaped():
    r0 = batch_at(CFG, SHAPE, 2, DataCfg(dp_rank=0, dp_size=4))["tokens"]
    r1 = batch_at(CFG, SHAPE, 2, DataCfg(dp_rank=1, dp_size=4))["tokens"]
    assert r0.shape == (2, 32)
    assert not np.array_equal(r0, r1)


def test_tokens_in_vocab():
    t = batch_at(CFG, SHAPE, 0)["tokens"]
    assert int(t.min()) >= 0 and int(t.max()) < CFG.vocab_size


def test_modality_stubs():
    wcfg = get_smoke_config("whisper-small")
    b = batch_at(wcfg, SHAPE, 0)
    assert b["frames"].shape == (8, wcfg.num_audio_frames, wcfg.d_model)
    vcfg = get_smoke_config("llama-3.2-vision-11b")
    b = batch_at(vcfg, SHAPE, 0)
    assert b["image_embed"].shape == (8, vcfg.num_image_tokens, vcfg.d_model)
