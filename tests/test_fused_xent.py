"""Fused chunked CE == plain CE (the §Perf loss-path optimization)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.models.model import _xent, fused_xent


def _case(B, S, d, V, seed=0):
    k = jax.random.key(seed)
    x = jax.random.normal(jax.random.fold_in(k, 1), (B, S, d))
    head = jax.random.normal(jax.random.fold_in(k, 2), (V, d)) * 0.1
    tokens = jax.random.randint(jax.random.fold_in(k, 3), (B, S), 0, V)
    return x, head, tokens


@given(B=st.integers(1, 4), S=st.integers(2, 70), d=st.integers(1, 32),
       V=st.integers(2, 100), chunk=st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_fused_equals_plain(B, S, d, V, chunk):
    x, head, tokens = _case(B, S, d, V, seed=B * 1000 + S)
    logits = jnp.einsum("bsd,vd->bsv", x, head)
    want = float(_xent(logits, tokens))
    got = float(fused_xent(x, tokens, head, chunk=chunk))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_grads_match():
    x, head, tokens = _case(2, 33, 16, 50)

    def f_plain(x, h):
        return _xent(jnp.einsum("bsd,vd->bsv", x, h), tokens)

    def f_fused(x, h):
        return fused_xent(x, tokens, h, chunk=8)

    g1 = jax.grad(f_plain, argnums=(0, 1))(x, head)
    g2 = jax.grad(f_fused, argnums=(0, 1))(x, head)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
