"""Distributed medoid engine + partitioning: subprocess tests with 8 fake
devices (the XLA device-count flag must be set before jax init, so these run
in their own interpreter)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # multi-device subprocess tests: own CI shard

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_distributed_corrsh_matches_exact():
    out = _run("""
import jax, jax.numpy as jnp, json
from repro.core.distributed import distributed_corr_sh, make_row_sharding
from repro.core import exact_medoid
mesh = jax.make_mesh((4, 2), ("data", "model"))
n, d = 512, 32
x = jax.random.normal(jax.random.key(1), (n, d))
x = x.at[: n // 3].mul(0.25)
xs = jax.device_put(x, make_row_sharding(mesh))
truth = int(exact_medoid(x, "l1"))
got_halving = int(distributed_corr_sh(xs, jax.random.key(7), mesh, budget=n*40, metric="l1"))
got_exact = int(distributed_corr_sh(xs, jax.random.key(7), mesh, budget=n*n*20, metric="l1"))
print(json.dumps({"truth": truth, "halving": got_halving, "exact": got_exact}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["exact"] == r["truth"]
    assert r["halving"] == r["truth"]


def test_distributed_matches_single_device_distribution():
    """Same seed, same data: the distributed engine must agree with the
    single-device reference at exact-budget (deterministic)."""
    out = _run("""
import jax, jax.numpy as jnp, json
from repro.core.distributed import distributed_corr_sh, make_row_sharding
from repro.core import correlated_sequential_halving
mesh = jax.make_mesh((8,), ("data",))
n, d = 256, 16
x = jax.random.normal(jax.random.key(3), (n, d))
xs = jax.device_put(x, make_row_sharding(mesh))
a = int(distributed_corr_sh(xs, jax.random.key(0), mesh, budget=n*n*10, metric="l2"))
b = int(correlated_sequential_halving(x, n*n*10, jax.random.key(0), "l2").medoid)
print(json.dumps({"dist": a, "single": b}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["dist"] == r["single"]


def test_distributed_v2_matches_exact():
    """The communication-optimal engine (stratified refs, two-mode rounds)
    must agree with exact computation and stay reliable under halving."""
    out = _run("""
import jax, jax.numpy as jnp, json
from repro.core.distributed import make_row_sharding
from repro.core.distributed_v2 import distributed_corr_sh_v2
from repro.core import exact_medoid
mesh = jax.make_mesh((4, 2), ("data", "model"))
n, d = 1024, 64
x = jax.random.normal(jax.random.key(1), (n, d))
x = x.at[: n // 3].mul(0.25)
xs = jax.device_put(x, make_row_sharding(mesh))
truth = int(exact_medoid(x, "l2"))
hits = sum(int(distributed_corr_sh_v2(xs, jax.random.key(100+s), mesh,
                                      budget=n*40, metric="l2")) == truth
           for s in range(5))
ex = int(distributed_corr_sh_v2(xs, jax.random.key(0), mesh,
                                budget=n*n*20, metric="l2"))
print(json.dumps({"truth": truth, "hits": hits, "exact": ex}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["exact"] == r["truth"]
    assert r["hits"] >= 4


def test_distributed_v2_tied_estimates_regression():
    """Tied theta estimates (repeated one-hot rows) must not over-keep
    survivors in v2's in-place mode: the engine still finds the planted
    medoid and matches the exact-budget answer. Regression for the
    value-threshold tie bug (see distributed_v2.survivor_keep_mask)."""
    out = _run("""
import jax, jax.numpy as jnp, json
from repro.core.distributed import make_row_sharding
from repro.core.distributed_v2 import distributed_corr_sh_v2
from repro.core import exact_medoid
mesh = jax.make_mesh((8,), ("data",))
n = 256
# 16 copies of each of 8 one-hot rows + 128 zero rows: estimates tie in
# droves, and the zero block contains the unambiguous medoid.
ones = jnp.tile(jnp.eye(8, 16), (16, 1))
x = jnp.concatenate([ones, jnp.zeros((128, 16))]).astype(jnp.float32)
xs = jax.device_put(x, make_row_sharding(mesh))
truth = int(exact_medoid(x, "l1"))
hits = sum(int(distributed_corr_sh_v2(xs, jax.random.key(50 + s), mesh,
                                      budget=n*40, metric="l1")) == truth
           for s in range(5))
ex = int(distributed_corr_sh_v2(xs, jax.random.key(1), mesh,
                                budget=n*n*20, metric="l1"))
print(json.dumps({"truth": truth, "hits": hits, "exact": ex}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["exact"] == r["truth"]
    assert r["hits"] >= 4


def test_distributed_backend_parity():
    """Pallas backends must agree with reference inside shard_map too."""
    out = _run("""
import jax, jax.numpy as jnp, json
from repro.core.distributed import distributed_corr_sh, make_row_sharding
from repro.core.distributed_v2 import distributed_corr_sh_v2
mesh = jax.make_mesh((8,), ("data",))
n, d = 256, 24
x = jax.random.normal(jax.random.key(1), (n, d))
xs = jax.device_put(x, make_row_sharding(mesh))
res = {}
for be in ("reference", "pallas_fused"):
    res["v1_" + be] = int(distributed_corr_sh(xs, jax.random.key(7), mesh,
                                              budget=n*30, metric="l2",
                                              backend=be))
    res["v2_" + be] = int(distributed_corr_sh_v2(xs, jax.random.key(7), mesh,
                                                 budget=n*30, metric="l1",
                                                 backend=be))
print(json.dumps(res))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["v1_reference"] == r["v1_pallas_fused"]
    assert r["v2_reference"] == r["v2_pallas_fused"]


def test_production_mesh_shapes():
    out = _run("""
import jax, json
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
print(json.dumps({"single": [m1.devices.shape, list(m1.axis_names)],
                  "multi": [m2.devices.shape, list(m2.axis_names)]}))
""", devices=512)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["single"] == [[16, 16], ["data", "model"]]
    assert r["multi"] == [[2, 16, 16], ["pod", "data", "model"]]


def test_param_specs_divisible_on_production_mesh():
    """Every spec produced by the partitioner must divide its dim on the
    production mesh, for every architecture (the dry-run precondition)."""
    out = _run("""
import jax, json
from repro.configs import ARCH_NAMES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import partition
from repro.models.model import build_model
mesh = make_production_mesh(multi_pod=True)
sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
bad = []
for arch in ARCH_NAMES:
    cfg = get_config(arch)
    model = build_model(cfg)
    shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = partition.param_specs(shape, cfg, mesh)
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shape)[0],
            jax.tree_util.tree_flatten_with_path(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0]):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None: continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            k = 1
            for a in axes: k *= sizes[a]
            if dim % k: bad.append((arch, str(path), dim, str(spec)))
print(json.dumps(bad))
""", devices=512)
    bad = json.loads(out.strip().splitlines()[-1])
    assert not bad, bad


def test_train_driver_multidevice_and_elastic_resume(tmp_path):
    """Train 6 steps on 8 devices, checkpoint, then resume on 4 devices —
    the elastic-reshard restart path."""
    code = """
import json
from repro.launch.train import train
out = train("internlm2-1.8b", smoke=True, steps=6, batch_size=8, seq_len=32,
            ckpt_dir=%r, ckpt_every=3)
print(json.dumps(out))
"""
    out1 = _run(code % str(tmp_path), devices=8)
    r1 = json.loads(out1.strip().splitlines()[-1])
    assert r1["steps"] == 6
    out2 = _run(code.replace("steps=6", "steps=9") % str(tmp_path), devices=4)
    r2 = json.loads(out2.strip().splitlines()[-1])
    assert r2["steps"] == 9
