"""AdamW, LR schedules, gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.optim import adamw, compress, schedule


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    target = jnp.asarray([1.0, 2.0, -1.0])
    state = adamw.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(g, state, params, lr=5e-2,
                                        weight_decay=0.0)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5


def test_schedule_warmup_and_decay():
    lr0 = float(schedule.cosine_with_warmup(0, peak_lr=1.0, warmup_steps=10,
                                            total_steps=100))
    lr_peak = float(schedule.cosine_with_warmup(10, peak_lr=1.0,
                                                warmup_steps=10, total_steps=100))
    lr_end = float(schedule.cosine_with_warmup(100, peak_lr=1.0,
                                               warmup_steps=10, total_steps=100))
    assert lr0 == 0.0 and abs(lr_peak - 1.0) < 1e-6 and lr_end < 0.2


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_bounded(seed):
    g = jax.random.normal(jax.random.key(seed), (1000,)) * 10
    rt = compress.compress_decompress(g)
    scale = jnp.max(jnp.abs(g.reshape(-1, 250)), axis=1)  # block bound
    # int8 block quantization error <= scale/254 per element
    err = jnp.abs(rt - g).max()
    assert float(err) <= float(jnp.max(scale)) / 127.0 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """With error feedback, the SUM of transmitted grads tracks the sum of
    true grads (residual stays bounded)."""
    key = jax.random.key(0)
    ef = compress.init_error_feedback({"w": jnp.zeros((256,))})
    total_true = jnp.zeros((256,))
    total_sent = jnp.zeros((256,))
    for t in range(50):
        g = {"w": jax.random.normal(jax.random.fold_in(key, t), (256,))}
        sent, ef = compress.apply_error_feedback(g, ef)
        total_true += g["w"]
        total_sent += sent["w"]
    resid = ef.error["w"]
    np.testing.assert_allclose(total_sent + resid, total_true,
                               rtol=1e-4, atol=1e-4)
    assert float(jnp.abs(resid).max()) < 0.2   # residual bounded, not growing
