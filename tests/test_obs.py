"""Observability subsystem: device telemetry, traces, serving metrics.

The PR-7 contract under test:

* **telemetry is free of observable effect** — with ``telemetry=True`` the
  winners, estimates, and pull counts are bitwise identical to
  ``telemetry=False``, on every backend, for the single / batched / ragged
  facade paths AND for the BUILD/SWAP estimators driven through
  ``run_halving`` directly (the stats are pure extra scan outputs over the
  same key sequence);
* **fixed shapes** — telemetry buffers are ``(R,)`` per query (``(B, R)``
  under the vmapped engines) with the schema of
  :data:`repro.obs.telemetry.FIELDS`, where R is the executed-round count —
  a static property of ``(n, budget)``;
* **exact accounting** — the per-round ``pulls`` column matches the round
  schedule row-for-row and sums to the facade's scheduled totals;
* **no new programs** — the telemetry variant compiles once per signature
  (like any program) and repeated calls trace nothing;
* **artifacts validate** — TraceSession JSONL streams and Prometheus
  expositions round-trip through :mod:`repro.obs.validate`, including the
  round-vs-select pull reconciliation and the +Inf-bucket == count
  histogram invariant.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import find_medoid, find_medoids_batch, find_medoids_ragged
from repro.core.backend import get_backend
from repro.engine import (HalvingProblem, build_delta, instrument,
                          round_schedule, run_halving, stop_round, swap_delta)
from repro.obs import (ServerMetrics, TraceSession, telemetry,
                       telemetry_to_host)
from repro.obs.validate import validate_exposition, validate_trace

pytestmark = pytest.mark.obs

BACKENDS = ("reference", "pallas_pairwise", "pallas_fused",
            "pallas_fused_topk")


def _executed(n: int, budget: int):
    rounds = round_schedule(n, budget)
    return rounds[: stop_round(rounds) + 1]


# --------------------------- bitwise answer parity ---------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_single_query_parity_and_accounting(backend):
    data = jax.random.normal(jax.random.key(0), (64, 5))
    kw = dict(budget_per_arm=17, backend=backend)
    off = find_medoid(data, jax.random.key(1), **kw)
    on = find_medoid(data, jax.random.key(1), telemetry=True, **kw)
    assert on.medoid == off.medoid
    assert on.pulls == off.pulls
    tel = on.telemetry
    executed = _executed(64, 17 * 64)
    assert set(tel) == set(telemetry.FIELDS)
    assert all(v.shape == (len(executed),) for v in tel.values())
    # schedule columns match the static plan row-for-row; measured columns
    # are finite where >= 2 arms were alive
    assert tel["pulls"].tolist() == [r.pulls for r in executed]
    assert tel["survivors"].tolist() == [r.survivors for r in executed]
    assert tel["num_refs"].tolist() == [r.num_refs for r in executed]
    assert int(tel["pulls"].sum()) == off.pulls
    assert tel["alive"].tolist()[0] == 64
    assert np.isfinite(tel["theta_med"]).all()
    assert float(tel["budget_frac"][-1]) == pytest.approx(1.0, abs=1e-5)


def test_batch_parity_and_vmap_shapes():
    data = jax.random.normal(jax.random.key(2), (3, 32, 4))
    off = np.asarray(find_medoids_batch(data, jax.random.key(3),
                                        budget_per_arm=11))
    on, tel = find_medoids_batch(data, jax.random.key(3), budget_per_arm=11,
                                 telemetry=True)
    assert np.array_equal(off, np.asarray(on))
    r = len(_executed(32, 11 * 32))
    assert all(v.shape == (3, r) for v in tel.values())
    # schedule columns broadcast across the batch; every query pays them
    assert np.array_equal(tel["pulls"][0], tel["pulls"][2])
    assert (tel["pulls"].sum(axis=1) == sum(
        x.pulls for x in _executed(32, 11 * 32))).all()


def test_ragged_parity_and_alive_column():
    qs = [jax.random.normal(jax.random.fold_in(jax.random.key(4), i), (n, 4))
          for i, n in enumerate((7, 21, 64))]     # all bucket to 64
    off = np.asarray(find_medoids_ragged(qs, key=jax.random.key(5),
                                         budget_per_arm=13))
    on, tel = find_medoids_ragged(qs, key=jax.random.key(5),
                                  budget_per_arm=13, telemetry=True)
    assert np.array_equal(off, np.asarray(on))
    # round 0's alive count is each query's true length — padding is
    # masked out of the telemetry exactly as it is out of the estimates
    assert tel["alive"][:, 0].tolist() == [7, 21, 64]
    # schedule columns are the bucket's (shared by every slot)
    assert np.array_equal(tel["survivors"][0], tel["survivors"][1])


@pytest.mark.parametrize("phase", ["build", "swap"])
def test_cluster_estimators_telemetry_neutral(phase):
    n, k = 40, 2
    data = jax.random.normal(jax.random.key(6), (n, 4))
    pw = get_backend("reference").pairwise("l2")
    dist = pw(data, data)                                  # (n, n)
    meds = jnp.array([3, 29])
    to_meds = dist[:, meds]                                # (n, k)
    nearest = jnp.argmin(to_meds, axis=1)
    d1 = jnp.min(to_meds, axis=1)
    d2 = jnp.max(to_meds, axis=1)                          # k=2: the other one
    if phase == "build":
        est = build_delta(metric="l2", d1=d1)
    else:
        est = swap_delta(metric="l2", d1=d1, d2=d2, nearest=nearest, k=k)
    rounds = round_schedule(n, 15 * n)
    problem = HalvingProblem(data, est)
    off = run_halving(problem, rounds, key=jax.random.key(7))
    on = run_halving(problem, rounds, key=jax.random.key(7), telemetry=True)
    assert int(on.winner) == int(off.winner)
    assert np.array_equal(np.asarray(on.theta), np.asarray(off.theta),
                          equal_nan=True)
    assert off.telemetry is None
    tel = telemetry_to_host(on.telemetry)
    assert tel["pulls"].tolist() == [
        r.pulls for r in rounds[: on.r_stop + 1]]


# ------------------------- program cache neutrality --------------------------

def test_telemetry_compiles_once_then_never():
    data = jax.random.normal(jax.random.key(8), (45, 3))
    kw = dict(budget_per_arm=9, backend="reference")
    with instrument.deltas() as first:
        find_medoid(data, jax.random.key(9), telemetry=True, **kw)
        find_medoid(data, jax.random.key(9), **kw)
    # each variant is its own cached program — at most one trace apiece
    assert first.trace("medoid") <= 2
    with instrument.deltas() as rerun:
        find_medoid(data, jax.random.key(9), telemetry=True, **kw)
        find_medoid(data, jax.random.key(9), **kw)
    assert rerun.trace() == 0            # both variants already cached
    assert rerun.dispatch("medoid") == 2


def test_deltas_freeze_on_exit():
    data = jax.random.normal(jax.random.key(10), (19, 3))
    find_medoid(data, jax.random.key(11), budget_per_arm=7)   # prime cache
    with instrument.deltas() as d:
        find_medoid(data, jax.random.key(11), budget_per_arm=7)
        assert d.dispatch("medoid") == 1          # readable mid-block
    frozen = d.counters()
    find_medoid(data, jax.random.key(11), budget_per_arm=7)   # after exit
    assert d.counters() == frozen                 # exit froze the deltas
    assert d.dispatch("medoid") == 1


# ------------------------------ facade edges --------------------------------

def test_telemetry_requires_corr_sh():
    data = jnp.zeros((4, 2))
    with pytest.raises(ValueError, match="telemetry"):
        find_medoid(data, jax.random.key(0), algo="exact", telemetry=True)


def test_single_point_yields_empty_rows():
    res = find_medoid(jnp.zeros((1, 3)), jax.random.key(0), telemetry=True)
    assert res.medoid == 0 and res.pulls == 0
    assert set(res.telemetry) == set(telemetry.FIELDS)
    assert all(v.shape == (0,) for v in res.telemetry.values())


# ------------------------------ trace sessions -------------------------------

def test_trace_session_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    data = jax.random.normal(jax.random.key(12), (33, 4))
    with TraceSession(path, meta={"workload": "test"}) as sess:
        with sess.span("query"):
            res = find_medoid(data, jax.random.key(13), budget_per_arm=8,
                              telemetry=True)
        sess.record_result(res)
    summary = validate_trace(path)      # checks seq, schema, pull sums
    assert summary["selects"] == 1
    assert summary["rounds"] == len(_executed(33, 8 * 33))
    span = next(e for e in sess.events if e["event"] == "span")
    assert span["name"] == "query" and span["dur_s"] >= 0
    assert span["dispatches"].get("medoid") == 1
    with pytest.raises(RuntimeError):
        sess.event("late")              # closed sessions refuse writes


def test_validator_rejects_bad_pull_accounting(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with TraceSession(path) as sess:
        sess.event("round", r=0, **{k: 1 for k in telemetry.FIELDS})
        sess.event("select", winner=0, pulls=999)    # != round sum
    with pytest.raises(ValueError, match="round records sum"):
        validate_trace(path)


# ------------------------------ serving metrics ------------------------------

def test_server_metrics_and_trace(tmp_path):
    from repro.launch.serve_medoid import MedoidServer, synthetic_trace

    queries = synthetic_trace(5, 8, 60, 4, seed=21)
    path = str(tmp_path / "srv.jsonl")
    with TraceSession(path) as sess:
        srv = MedoidServer(budget_per_arm=9, max_batch=4, seed=2, trace=sess)
        plain = MedoidServer(budget_per_arm=9, max_batch=4, seed=2)
        for q in queries:
            srv.submit(q)
            plain.submit(q)
        srv.drain()
        plain.drain()
    # tracing a server never changes its answers
    assert {r: q.medoid for r, q in srv.done.items()} \
        == {r: q.medoid for r, q in plain.done.items()}
    summary = validate_trace(path)
    assert summary["selects"] == 5
    snap = srv.metrics()
    assert sum(s["value"] for s in
               snap["medoid_answered_total"]["series"]) == 5
    occ = snap["medoid_batch_occupancy"]["series"]
    assert sum(s["count"] for s in occ) == srv.dispatches
    mpath = tmp_path / "srv.txt"
    mpath.write_text(srv.exposition())
    got = validate_exposition(str(mpath))
    assert got["families"] >= 7         # 7 server families + odometers
    assert "medoid_dispatch_seconds_bucket" in mpath.read_text()


def test_server_metrics_phase_split():
    m = ServerMetrics()
    m.record_submit("64x4")
    m.record_dispatch("64x4", wall_s=1.5, batch=2, slots=4,
                      pulls_per_request=100, waits=[0, 1], compiled=True)
    m.record_dispatch("64x4", wall_s=0.002, batch=4, slots=4,
                      pulls_per_request=100, waits=[0, 0, 1, 2],
                      compiled=False)
    snap = m.snapshot()
    series = {tuple(sorted(s["labels"].items())): s["value"]
              for s in snap["medoid_dispatches_total"]["series"]}
    assert series[(("bucket", "64x4"), ("phase", "compile"))] == 1
    assert series[(("bucket", "64x4"), ("phase", "steady"))] == 1
    assert sum(s["value"] for s in
               snap["medoid_pulls_total"]["series"]) == 600
    with pytest.raises(ValueError, match="only go up"):
        m.requests.labels("64x4").inc(-1)


def test_cluster_service_routes():
    from repro.cluster.service import ClusterService, kmedoids_via_service

    data = jax.random.normal(jax.random.key(14), (96, 5))
    res, srv = kmedoids_via_service(data, 3, jax.random.key(15))
    svc = ClusterService(srv)
    assert svc.routes() == ("/buckets", "/metrics", "/stats")
    stats = svc.handle("/stats")
    assert stats["answered"] == len(srv.done)
    assert "medoid_requests_total" in stats["metrics"]
    assert "# TYPE medoid_requests_total counter" in svc.handle("/metrics")
    assert svc.handle("/buckets")["dispatches"] == srv.dispatches
    with pytest.raises(KeyError, match="/nope"):
        svc.handle("/nope")


# --------------------------------- CLI smoke ---------------------------------

def test_launch_medoid_trace_cli(tmp_path, capsys):
    from repro.launch import medoid as launch_medoid
    from repro.obs.validate import main as validate_main

    tpath = str(tmp_path / "m.jsonl")
    mpath = str(tmp_path / "m.txt")
    launch_medoid.main(["--n", "48", "--d", "4", "--budget-per-arm", "8",
                        "--trace", tpath, "--metrics-out", mpath])
    out = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert sum(out["telemetry"]["pulls"]) == out["pulls_scheduled"]
    assert validate_main([tpath, mpath]) == 0
    assert validate_trace(tpath)["selects"] == 1
