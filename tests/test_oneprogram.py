"""One-program execution model: retrace safety, donation, host-sync guards.

The PR-6 contract under test:

* **retrace exactly once** — repeated same-shape ``find_medoid`` /
  ``find_medoids_ragged`` / ``kmedoids`` calls trace one XLA program per
  distinct signature and zero afterwards (counter-based, via the monotone
  odometers of :mod:`repro.engine.instrument`);
* **donation is safe and folded** — on CPU the donate flag folds away so
  donating and plain callers share one compiled program; the facade's
  self-packed (donated) ragged path answers identically to the caller-packed
  (non-donated) path;
* **no host syncs in the hot path** — the engine package, the device-path
  telemetry module (``repro.obs.telemetry``) and the cluster BUILD/SWAP
  phase kernels contain no ``.item()`` / ``np.asarray`` / ``device_get``
  (source-level guard, mirrored by the CI grep);
* **stacked schedules** — ``Schedule.stacked`` partitions exactly the
  scanned prefix ``[0, r_stop)`` into bands with the legacy entering sizes;
* **warmup + persistent cache** — a warmed ``MedoidServer`` serves known
  buckets with zero recompiles, and ``enable_persistent_cache`` writes XLA
  cache entries a restarted process can reuse.
"""
import inspect
import math
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.api import find_medoid, find_medoids_ragged, kmedoids
from repro.core.bucketing import pack_queries
from repro.engine import instrument, programs
from repro.engine.schedule import Schedule, as_schedule, round_schedule

pytestmark = pytest.mark.engine

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")


# ------------------------------ retrace safety ------------------------------

def test_find_medoid_traces_exactly_once():
    data = jax.random.normal(jax.random.key(0), (37, 5))
    kw = dict(budget_per_arm=23, metric="l2", backend="reference")
    with instrument.deltas() as first:
        a = find_medoid(data, jax.random.key(1), **kw).medoid
    assert first.trace("medoid") <= 1  # 0 only if identical config ran earlier
    assert first.dispatch("medoid") == 1
    with instrument.deltas() as rerun:
        for i in range(3):          # same shape+config: never again
            b = find_medoid(data, jax.random.key(1), **kw).medoid
            assert b == a
    assert rerun.trace("medoid") == 0
    assert rerun.dispatch("medoid") == 3


def test_ragged_traces_once_per_bucket():
    qs = [jax.random.normal(jax.random.fold_in(jax.random.key(2), i), (n, 4))
          for i, n in enumerate((11, 29, 43))]   # all bucket to 64
    with instrument.deltas() as first:
        a = find_medoids_ragged(qs, key=jax.random.key(3), budget_per_arm=19)
    assert first.trace("ragged") <= 1
    with instrument.deltas() as rerun:
        b = find_medoids_ragged(qs, key=jax.random.key(3), budget_per_arm=19)
    assert [int(x) for x in a] == [int(x) for x in b]
    assert rerun.trace("ragged") == 0


def test_kmedoids_identical_rerun_traces_nothing():
    data = jax.random.normal(jax.random.key(4), (40, 6))
    res = kmedoids(data, 3, jax.random.key(5), build_budget_per_arm=13,
                   swap_budget_per_arm=13, refine_budget_per_arm=13)
    with instrument.deltas() as d:
        res2 = kmedoids(data, 3, jax.random.key(5), build_budget_per_arm=13,
                        swap_budget_per_arm=13, refine_budget_per_arm=13)
    assert d.trace() == 0                         # every program is cached
    assert d.counters()["traces"] == {}           # per-kind deltas agree
    assert (res2.medoids, res2.pulls, res2.swaps) == \
        (res.medoids, res.pulls, res.swaps)


# -------------------------------- donation ----------------------------------

def test_donation_flag_folds_away_on_cpu():
    kw = dict(budget=37 * 21, metric="l2", backend="reference")
    if jax.default_backend() == "cpu":
        assert not programs.donation_enabled()
        # one program for both flags: no double compile, no CPU warning spam
        assert programs.medoid_program(donate=True, **kw) \
            is programs.medoid_program(donate=False, **kw)
    else:
        assert programs.donation_enabled()
        assert programs.medoid_program(donate=True, **kw) \
            is not programs.medoid_program(donate=False, **kw)


def test_donated_facade_path_matches_nondonated():
    qs = [jax.random.normal(jax.random.fold_in(jax.random.key(6), i), (n, 4))
          for i, n in enumerate((17, 51))]
    # list input: the facade packs (and donates) the buffer itself
    a = find_medoids_ragged(qs, key=jax.random.key(7), budget_per_arm=19)
    # caller-packed input: never donated, caller's buffer must survive
    data, lens = pack_queries(qs)
    b = find_medoids_ragged(data, lens, jax.random.key(7), budget_per_arm=19)
    assert [int(x) for x in a] == [int(x) for x in b]
    assert data.shape == (2, 64, 4)               # still alive and readable
    assert bool(jnp.isfinite(data).all())


# ----------------------- host-sync source-level guard -----------------------

FORBIDDEN = (r"\.item\(", r"device_get", r"\bnp\.asarray")  # \b spares jnp.


def test_no_host_syncs_in_engine_package():
    import repro.engine.estimators
    import repro.engine.halving
    import repro.engine.programs
    import repro.engine.schedule
    import repro.obs.telemetry
    # repro.obs.telemetry is device-path: its stats ride the scanned round
    # loop, so it lives under the same guard as the engine package (the
    # host-side obs modules — trace/metrics — legitimately sync)
    for mod in (repro.engine.halving, repro.engine.estimators,
                repro.engine.programs, repro.engine.schedule,
                repro.obs.telemetry):
        src = inspect.getsource(mod)
        for pat in FORBIDDEN:
            assert not re.search(pat, src), f"{pat!r} found in {mod.__name__}"


def test_no_host_syncs_in_cluster_phase_kernels():
    from repro.cluster import kmedoids as km
    for fn in (km._build_step, km._build_scan, km._assign, km._top2_of,
               km._swap_argmin, km._exact_swap_delta, km._swap_sweep_impl):
        src = inspect.getsource(fn)
        for pat in FORBIDDEN:
            assert not re.search(pat, src), f"{pat!r} found in {fn.__name__}"


# ----------------------------- stacked schedules ----------------------------

def test_stacked_partitions_scanned_prefix():
    for n, per_arm in ((512, 16), (300, 10), (17, 3), (4096, 24)):
        sched = Schedule.from_budget(n, per_arm * n)
        stk = sched.stacked(n)
        # entering sizes follow the legacy halving recursion from n
        assert stk.sizes[0] == n
        for a, b in zip(stk.sizes, stk.sizes[1:]):
            assert b == math.ceil(a / 2)
        # bands tile [0, r_stop) exactly, in order, at the entering width
        covered = []
        for band in stk.bands:
            assert band.width == stk.sizes[band.start]
            assert band.ref_cap == max(band.num_refs)
            assert band.survivors == tuple(
                stk.sizes[band.start:band.start + len(band)])
            covered.extend(range(band.start, band.start + len(band)))
        assert covered == list(range(stk.r_stop))
        # the output round is static: exact or <= 2 entering arms
        rd = sched[stk.r_stop]
        assert rd.exact or stk.sizes[stk.r_stop] <= 2


def test_stacked_band_rounds_knob_and_errors():
    sched = Schedule.from_budget(512, 16 * 512)
    ones = sched.stacked(512, band_rounds=1)
    assert all(len(b) == 1 for b in ones.bands)
    big = sched.stacked(512, band_rounds=64)
    assert len(big.bands) == 1 and big.r_stop == ones.r_stop
    with pytest.raises(ValueError, match="band_rounds"):
        sched.stacked(512, band_rounds=0)
    with pytest.raises(ValueError, match="empty"):
        Schedule(()).stacked(1)
    assert as_schedule(round_schedule(64, 640)).rounds \
        == Schedule.from_budget(64, 640).rounds


# ------------------------- warmup + persistent cache ------------------------

def test_warmed_server_never_recompiles():
    from repro.launch.serve_medoid import MedoidServer, synthetic_trace

    srv = MedoidServer(budget_per_arm=21, max_batch=4, seed=0)
    trace = synthetic_trace(6, 16, 100, 5, seed=3)
    stats = srv.warmup(sorted({(q.shape[0], q.shape[1]) for q in trace}))
    assert set(stats) == {"buckets", "traces", "wall_s"}
    for q in trace:
        srv.submit(q)
    srv.drain()
    assert len(srv.done) == 6
    assert srv.recompiles == 0     # every bucket was pre-traced by warmup


@pytest.mark.slow
def test_persistent_cache_writes_entries(tmp_path):
    code = """
import os, sys
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp
from repro.engine import programs
path = programs.enable_persistent_cache(sys.argv[1])
fn = programs.medoid_program(budget=13 * 16)
fn(jnp.zeros((16, 3)), jax.random.key(0)).block_until_ready()
print(len(os.listdir(path)))
"""
    env = dict(os.environ, PYTHONPATH=_SRC)
    out = subprocess.run([sys.executable, "-c", code, str(tmp_path)],
                         capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr
    assert int(out.stdout.strip().splitlines()[-1]) >= 1
