"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-lite-16b
"""
import argparse
import json

import jax

from repro.launch.serve import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    srv = Server(args.arch, smoke=True, batch_slots=3, max_len=96)
    key = jax.random.key(0)
    reqs = [Request(rid=i,
                    prompt=jax.random.randint(jax.random.fold_in(key, i),
                                              (12,), 0, srv.cfg.vocab_size),
                    max_new=args.max_new)
            for i in range(args.requests)]
    stats = srv.run(reqs)
    print(json.dumps(stats, indent=2))
    for r in reqs:
        print(f"request {r.rid}: generated {r.out}")


if __name__ == "__main__":
    main()
