"""Serve a small model with batched requests (continuous batching), plus the
batched medoid engine as a sidecar service.

LM serving and medoid identification share the serving pattern: many
independent queries, one device dispatch. ``--medoid-batch B`` answers B
"representative selection" queries (each: pick the medoid of a candidate
embedding set, e.g. for prompt-cache clustering or retrieval dedup) in a
single ``repro.api.find_medoids_batch`` call on the selected distance backend.

    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-lite-16b
    PYTHONPATH=src python examples/serve_lm.py --medoid-batch 8 \
        --medoid-backend pallas_fused
"""
import argparse
import json
import time

import jax

from repro.api import find_medoids_batch
from repro.core import list_backends
from repro.launch.serve import Request, Server


def serve_medoid_queries(batch: int, backend: str, *, n: int = 512,
                         d: int = 64, budget_per_arm: int = 24,
                         seed: int = 0) -> dict:
    """Answer ``batch`` independent medoid queries in one dispatch."""
    key = jax.random.key(seed)
    sets = jax.random.normal(jax.random.fold_in(key, 1), (batch, n, d))
    t0 = time.time()
    medoids = find_medoids_batch(sets, jax.random.fold_in(key, 2),
                                 budget_per_arm=budget_per_arm,
                                 metric="cosine", backend=backend)
    medoids = [int(m) for m in medoids]
    return {"queries": batch, "n": n, "d": d, "backend": backend,
            "medoids": medoids, "batch_s": round(time.time() - t0, 3)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--medoid-batch", type=int, default=0,
                    help="also serve B batched medoid queries")
    ap.add_argument("--medoid-backend", default="pallas_fused",
                    choices=list(list_backends()))
    args = ap.parse_args()

    srv = Server(args.arch, smoke=True, batch_slots=3, max_len=96)
    key = jax.random.key(0)
    reqs = [Request(rid=i,
                    prompt=jax.random.randint(jax.random.fold_in(key, i),
                                              (12,), 0, srv.cfg.vocab_size),
                    max_new=args.max_new)
            for i in range(args.requests)]
    stats = srv.run(reqs)
    print(json.dumps(stats, indent=2))
    for r in reqs:
        print(f"request {r.rid}: generated {r.out}")

    if args.medoid_batch > 0:
        out = serve_medoid_queries(args.medoid_batch, args.medoid_backend)
        print("medoid sidecar:", json.dumps(out))


if __name__ == "__main__":
    main()
