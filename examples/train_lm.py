"""End-to-end LM training driver.

Default: a reduced internlm2 on CPU, 200 steps, with checkpoints + resume.
``--m100`` trains a ~100M-parameter config for a few hundred steps (sized for
real hardware; runs on CPU too, slowly).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --m100 --steps 300
"""
import argparse
import json

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--m100", action="store_true",
                    help="~100M-param config instead of the smoke config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.m100:
        # ~100M params: 12L x 768 with an 8k-ish vocab
        import repro.configs.registry as registry
        from repro.configs import get_smoke_config
        base = get_smoke_config(args.arch)
        cfg100 = base.scaled(num_layers=12, d_model=768, num_heads=12,
                             num_kv_heads=4, d_ff=3072, vocab_size=8192,
                             head_dim=64)
        registry.get_smoke_config = lambda name: cfg100  # inject
        out = train(args.arch, smoke=True, steps=args.steps, batch_size=8,
                    seq_len=512, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    else:
        out = train(args.arch, smoke=True, steps=args.steps, batch_size=8,
                    seq_len=128, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    print(json.dumps(out, indent=2))
    assert out["final_loss"] < out["first_loss"], "training must reduce loss"
    print("loss decreased — training works end to end "
          f"({out['first_loss']:.3f} -> {out['final_loss']:.3f})")


if __name__ == "__main__":
    main()
