"""The paper's technique wired into the LM stack: representative-example
selection over transformer hidden states via Correlated Sequential Halving.

Use case (data pruning / coreset selection): embed a pile of sequences with a
model, then pick the most-representative sequence = the medoid of the
embedding vectors — in O(n log n) distance evaluations instead of O(n^2).
Works with ANY of the 10 supported architectures (--arch).

    PYTHONPATH=src python examples/embedding_medoid.py --arch qwen2.5-14b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import corr_sh_medoid, exact_medoid, schedule_pulls
from repro.models import encdec as ED
from repro.models import recurrent as R
from repro.models import transformer as T
from repro.models.model import build_model


def embed_sequences(cfg, params, tokens, frames=None, image_embed=None):
    """Mean-pooled final hidden states — model-agnostic embedding."""
    if cfg.family in ("dense", "moe", "vlm"):
        logits, _, _ = T.transformer_forward(params, cfg, tokens,
                                             image_embed=image_embed)
    elif cfg.family == "ssm":
        logits, _ = R.xlstm_forward(params, cfg, tokens)
    elif cfg.family == "hybrid":
        logits, _ = R.hybrid_forward(params, cfg, tokens)
    elif cfg.family == "audio":
        enc = ED.encode(params, cfg, frames)
        logits, _ = ED.decode_train(params, cfg, tokens, enc)
    # logits as embedding proxy (mean over positions, f32)
    return jnp.mean(logits.astype(jnp.float32), axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--num-seqs", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)

    # synthesize a corpus in small batches and embed it
    embs = []
    bs = 32
    extra = {}
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    for i in range(args.num_seqs // bs):
        toks = jax.random.randint(jax.random.fold_in(key, i),
                                  (bs, args.seq_len), 0, cfg.vocab_size)
        kw = {}
        if cfg.family == "audio":
            kw["frames"] = jax.random.normal(
                jax.random.fold_in(key, 1000 + i),
                (bs, cfg.num_audio_frames, cfg.d_model), dt)
        if cfg.family == "vlm":
            kw["image_embed"] = jax.random.normal(
                jax.random.fold_in(key, 1000 + i),
                (bs, cfg.num_image_tokens, cfg.d_model), dt)
        embs.append(embed_sequences(cfg, params, toks, **kw))
    embs = jnp.concatenate(embs)                          # (n, V)
    n = embs.shape[0]
    print(f"embedded {n} sequences with {args.arch} (dim {embs.shape[1]})")

    budget = 20 * n
    t0 = time.time()
    rep = int(corr_sh_medoid(embs, jax.random.key(2), budget=budget,
                             metric="l2"))
    t_corr = time.time() - t0
    truth = int(exact_medoid(embs, "l2"))
    print(f"representative sequence (corrSH): #{rep}  "
          f"[{schedule_pulls(n, budget):,} pulls, {t_corr:.2f}s]")
    print(f"representative sequence (exact):  #{truth}  [{n * n:,} pulls]")
    print(f"match: {rep == truth}")


if __name__ == "__main__":
    main()
