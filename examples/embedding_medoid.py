"""The paper's technique wired into the LM stack: representative-example
selection over transformer hidden states via Correlated Sequential Halving.

Use case (data pruning / coreset selection): embed a pile of sequences with a
model, then pick the most-representative sequence = the medoid of the
embedding vectors — in O(n log n) distance evaluations instead of O(n^2).
Works with ANY of the 10 supported architectures (--arch).

    PYTHONPATH=src python examples/embedding_medoid.py --arch qwen2.5-14b

With ``--queries Q`` the corpus is split into Q uneven shards (per-topic /
per-tenant selection) and each shard's representative is answered through the
continuous-batching medoid service: queries are coalesced into power-of-two
shape buckets and dispatched through the ragged engine, so the Q mixed-size
queries share a handful of compiled programs instead of one per shard size.

    PYTHONPATH=src python examples/embedding_medoid.py --queries 6
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.api import find_medoid, kmedoids
from repro.core import exact_medoid
from repro.models import encdec as ED
from repro.models import recurrent as R
from repro.models import transformer as T
from repro.models.model import build_model


def embed_sequences(cfg, params, tokens, frames=None, image_embed=None):
    """Mean-pooled final hidden states — model-agnostic embedding."""
    if cfg.family in ("dense", "moe", "vlm"):
        logits, _, _ = T.transformer_forward(params, cfg, tokens,
                                             image_embed=image_embed)
    elif cfg.family == "ssm":
        logits, _ = R.xlstm_forward(params, cfg, tokens)
    elif cfg.family == "hybrid":
        logits, _ = R.hybrid_forward(params, cfg, tokens)
    elif cfg.family == "audio":
        enc = ED.encode(params, cfg, frames)
        logits, _ = ED.decode_train(params, cfg, tokens, enc)
    # logits as embedding proxy (mean over positions, f32)
    return jnp.mean(logits.astype(jnp.float32), axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--num-seqs", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--queries", type=int, default=1,
                    help="split the corpus into Q uneven shards and answer "
                         "each through the batched medoid service")
    ap.add_argument("--cluster", type=int, default=0, metavar="K",
                    help="bandit k-medoids over the embeddings: K "
                         "representative sequences, one per cluster")
    ap.add_argument("--backend", default="reference")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)

    # synthesize a corpus in small batches and embed it
    embs = []
    bs = 32
    extra = {}
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    for i in range(args.num_seqs // bs):
        toks = jax.random.randint(jax.random.fold_in(key, i),
                                  (bs, args.seq_len), 0, cfg.vocab_size)
        kw = {}
        if cfg.family == "audio":
            kw["frames"] = jax.random.normal(
                jax.random.fold_in(key, 1000 + i),
                (bs, cfg.num_audio_frames, cfg.d_model), dt)
        if cfg.family == "vlm":
            kw["image_embed"] = jax.random.normal(
                jax.random.fold_in(key, 1000 + i),
                (bs, cfg.num_image_tokens, cfg.d_model), dt)
        embs.append(embed_sequences(cfg, params, toks, **kw))
    embs = jnp.concatenate(embs)                          # (n, V)
    n = embs.shape[0]
    print(f"embedded {n} sequences with {args.arch} (dim {embs.shape[1]})")

    t0 = time.time()
    res = find_medoid(embs, jax.random.key(2), metric="l2",
                      budget_per_arm=20)
    rep = res.medoid
    t_corr = time.time() - t0
    truth = int(exact_medoid(embs, "l2"))
    print(f"representative sequence (corrSH): #{rep}  "
          f"[{res.pulls:,} pulls, {t_corr:.2f}s]")
    print(f"representative sequence (exact):  #{truth}  [{n * n:,} pulls]")
    print(f"match: {rep == truth}")

    if args.cluster > 1:
        # K representative sequences (coreset selection with coverage): bandit
        # k-medoids over the embeddings — BUILD/SWAP on the corrSH engine,
        # per-cluster refinement through the ragged bucketed dispatch
        t0 = time.time()
        res = kmedoids(embs, args.cluster, jax.random.key(3),
                       metric="l2", backend=args.backend)
        sizes = [int((res.labels == c).sum()) for c in range(args.cluster)]
        print(f"\n{args.cluster}-medoid clustering in {time.time() - t0:.2f}s "
              f"({res.pulls:,} pulls vs {n * n:,} exact, "
              f"{res.swaps} swaps, cost {res.cost:.1f}):")
        for c, (m, s) in enumerate(zip(res.medoids, sizes)):
            print(f"  cluster {c}: representative #{m}  ({s} sequences)")

    if args.queries > 1:
        # per-shard representatives via the continuous-batching service:
        # uneven shard sizes, bucketed dispatch, one answer per shard
        from repro.launch.serve_medoid import MedoidServer

        srv = MedoidServer(metric="l2", backend=args.backend,
                           budget_per_arm=24, max_batch=args.queries)
        bounds = sorted({int(x) for x in
                         (n * (i + 1) ** 1.5 / args.queries ** 1.5
                          for i in range(args.queries - 1))} | {n})
        shards, lo = [], 0
        for hi in bounds:
            if hi > lo:
                shards.append((lo, hi))
                lo = hi
        rids = {srv.submit(embs[a:b]): (a, b) for a, b in shards}
        t0 = time.time()
        srv.drain()
        print(f"\n{len(shards)} shard queries answered in "
              f"{srv.dispatches} dispatches "
              f"({srv.stats()['distinct_buckets']} buckets, "
              f"{srv.recompiles} compiles, {time.time() - t0:.2f}s):")
        for rid, (a, b) in rids.items():
            req = srv.done[rid]
            local = int(req.medoid)
            t_shard = int(exact_medoid(embs[a:b], "l2"))
            print(f"  shard [{a:4d},{b:4d}) n={b - a:4d}: "
                  f"representative #{a + local}  "
                  f"(exact match: {local == t_shard})")


if __name__ == "__main__":
    main()
