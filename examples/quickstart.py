"""Quickstart: find the medoid of a dataset 30-100x cheaper than exact.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.api import find_medoid, find_medoids_batch
from repro.core import exact_medoid, hardness_stats
from repro.data.medoid_datasets import rnaseq_like


def main():
    n, d = 2048, 512
    print(f"generating RNA-Seq-like dataset: n={n}, d={d} (l1 metric)")
    data = rnaseq_like(jax.random.key(0), n, d)

    t0 = time.time()
    res = find_medoid(data, jax.random.key(1), metric="l1",
                      budget_per_arm=24)  # ~24 distance evals per point
    medoid, pulls = res.medoid, res.pulls
    t_corr = time.time() - t0
    print(f"corrSH:  medoid={medoid}   pulls={pulls:,} "
          f"({pulls / n:.1f}/arm)  {t_corr:.2f}s")

    t0 = time.time()
    truth = int(exact_medoid(data, "l1"))
    t_exact = time.time() - t0
    print(f"exact:   medoid={truth}   pulls={n * n:,} "
          f"({n}/arm)  {t_exact:.2f}s")
    print(f"correct: {medoid == truth}   "
          f"pull reduction: {n * n / pulls:.0f}x   "
          f"speedup: {t_exact / max(t_corr, 1e-9):.1f}x")

    hs = hardness_stats(data, "l1")
    print(f"hardness: sigma={float(hs.sigma):.3f}  "
          f"H2={float(hs.h2):.3g}  H2~={float(hs.h2_tilde):.3g}  "
          f"ratio={float(hs.h2 / hs.h2_tilde):.1f} "
          f"(the paper's predicted correlation gain)")

    # Same algorithm on the fused Pallas backend: the per-round (s_r, t_r)
    # distance block is reduced inside the kernel and never reaches HBM.
    m_fused = find_medoid(data, jax.random.key(1), metric="l1",
                          budget_per_arm=24, backend="pallas_fused").medoid
    print(f"pallas_fused backend: medoid={m_fused} "
          f"(agrees: {m_fused == medoid})")

    # Batched multi-query engine: B candidate sets -> B medoids, one dispatch.
    b, nb = 4, 256
    sets = jax.random.normal(jax.random.key(2), (b, nb, 32))
    t0 = time.time()
    batch_medoids = find_medoids_batch(sets, jax.random.key(3), metric="l2",
                                       budget_per_arm=24)
    print(f"batched: {b} queries of n={nb} -> "
          f"{[int(m) for m in batch_medoids]}  {time.time() - t0:.2f}s")


if __name__ == "__main__":
    main()
